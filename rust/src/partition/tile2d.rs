//! 2D tile decomposition of the oriented adjacency matrix (after Tom &
//! Karypis, arXiv 1907.09575).
//!
//! The §IV algorithms all partition *rows* (1D): per-rank communication
//! grows as O(m) and the wire dominates once P is large. A 2D r×c
//! process grid assigns oriented edge `(v, u)` to tile
//! `(rowblk(v), colblk(u))`; counting then needs each rank to see only
//! its grid *row* of tiles (full rows `N_v` for `v ∈ R_i`) and its grid
//! *column* of tiles (full in-columns for `u ∈ C_j`), so per-rank traffic
//! is `m/r + m/c ≈ 2m/√P` — the O(m/√P) bound `algo::tile2d` realizes
//! over the coalescing layer.
//!
//! ## Grid factorization
//!
//! [`grid_for`] picks `(r, c)` minimizing the per-rank traffic factor
//! `1/r + 1/c` subject to `r·c ≤ P` (ties: fuller grid, then squarer):
//! P=2 → 1×2, 6 → 2×3, 8 → 2×4, 9 → 3×3, 16 → 4×4. When `r·c < P` the
//! leftover ranks form the **remainder row**: they hold an empty tile,
//! idle through the exchange and join the final reduce — trading a few
//! idle ranks for strictly less traffic than any exact factorization
//! (P=5 runs a 2×2 grid, not 1×5).
//!
//! ## The shuffle: why blocks are intervals of *shuffled* ids
//!
//! In degree order the oriented matrix is upper-triangular with its mass
//! piled against the hub corner, so consecutive id-intervals **cannot**
//! balance tiles: the last row block's out-edges can only land in the
//! last column blocks, the max tile grows ≈ √P faster than the average,
//! and per-rank broadcast bytes stop falling with P. [`shuffled`]
//! relabels the oriented graph by a seeded Fisher–Yates permutation
//! first (the same remedy as CombBLAS's random symmetric permutation for
//! 2D SpGEMM): over shuffled ids every interval block is a uniform
//! vertex sample, tiles concentrate to `m/(r·c)`, and the O(m/√P) bound
//! holds — while every interval/slice mechanism below stays intact. The
//! seed is fixed, so the driver, the simulator, `ft/` recovery and
//! `partition-stats` all derive the identical labeling (and identical
//! replay traces).
//!
//! ## Blocks and tiles
//!
//! Row blocks balance oriented out-degree (row-broadcast volume), column
//! blocks balance oriented *in*-degree (column-broadcast volume); both
//! are consecutive id-intervals, so a tile's row piece is one contiguous
//! subslice of `N_v`. Tiles are materialized as
//! [`OwnedPartition`]s through the same rebased-offsets machinery as the
//! 1D layouts ([`OwnedPartition::from_rows`]) — no rank captures the
//! shared graph, and measured residency equals [`TileSize::bytes`]
//! exactly (the same measured==predicted gate as PR 4's 1D layouts).

use std::ops::Range;

use crate::adj::hub::HubThreshold;
use crate::gen::rng::Rng;
use crate::graph::ordering::Oriented;
use crate::partition::balance::{balanced_ranges, OwnerTable};
use crate::partition::cost::prefix_sums;
use crate::partition::owned::OwnedPartition;
use crate::VertexId;

/// An r×c process grid over `P ≥ r·c` ranks. Rank `i·c + j` owns tile
/// `(i, j)`; ranks `≥ r·c` are the remainder row (empty tiles).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Grid {
    pub r: usize,
    pub c: usize,
}

impl Grid {
    /// Ranks holding a real tile (`r·c`).
    #[inline]
    pub fn active(&self) -> usize {
        self.r * self.c
    }

    /// Grid coordinates of `rank`, `None` for remainder ranks.
    #[inline]
    pub fn coords(&self, rank: usize) -> Option<(usize, usize)> {
        (rank < self.active()).then(|| (rank / self.c, rank % self.c))
    }

    /// Rank owning tile `(i, j)`.
    #[inline]
    pub fn rank_of(&self, i: usize, j: usize) -> usize {
        debug_assert!(i < self.r && j < self.c);
        i * self.c + j
    }
}

/// Nearest `r·c ≤ p` factorization minimizing per-rank traffic
/// `1/r + 1/c` (see module docs). `r ≤ c` always.
pub fn grid_for(p: usize) -> Grid {
    assert!(p >= 1, "grid needs at least one rank");
    let mut best = Grid { r: 1, c: p };
    let mut best_cost = f64::INFINITY;
    let mut r = 1usize;
    while r * r <= p {
        let c = p / r;
        let g = Grid { r, c };
        let cost = 1.0 / r as f64 + 1.0 / c as f64;
        let better = cost < best_cost - 1e-12
            || ((cost - best_cost).abs() <= 1e-12
                && (g.active() > best.active()
                    || (g.active() == best.active() && c - r < best.c - best.r)));
        if better {
            best = g;
            best_cost = cost;
        }
        r += 1;
    }
    best
}

/// Fixed seed of the tile shuffle. Changing it changes every tile
/// boundary — committed benchmarks and replay traces would shift.
const SHUFFLE_SEED: u64 = 0x7119_2d5e_ed00_91f3;

/// Degree-decorrelating relabel applied before tiling (see module docs):
/// a Fisher–Yates permutation under the fixed [`SHUFFLE_SEED`], so every
/// caller — driver, simulator, `ft/` recovery, `partition-stats` —
/// derives the identical labeling. The triangle count is invariant under
/// relabeling; [`layout`] / [`extract_tiles`] / [`count_tile_seq`] must
/// all be fed the *same* shuffled graph.
pub fn shuffled(o: &Oriented) -> Oriented {
    let n = o.num_nodes();
    let mut rng = Rng::seeded(SHUFFLE_SEED);
    let mut perm: Vec<VertexId> = (0..n as VertexId).collect();
    for i in (1..n).rev() {
        let j = rng.below_usize(i + 1);
        perm.swap(i, j);
    }
    o.relabeled(&perm, HubThreshold::default())
}

/// The full 2D decomposition for `procs` ranks: the grid plus the row /
/// column id-interval blocks. O(P) metadata, shared by every rank like
/// the 1D range list.
#[derive(Clone, Debug)]
pub struct TileLayout {
    pub grid: Grid,
    /// Total ranks (active grid + remainder row).
    pub procs: usize,
    /// `grid.r` consecutive id-intervals tiling `[0, n)` — balanced by
    /// oriented out-degree.
    pub row_blocks: Vec<Range<u32>>,
    /// `grid.c` consecutive id-intervals tiling `[0, n)` — balanced by
    /// oriented in-degree.
    pub col_blocks: Vec<Range<u32>>,
}

impl TileLayout {
    /// The tile index (== owning rank) of oriented edge `(v, u)`.
    pub fn tile_of(&self, v: VertexId, u: VertexId) -> usize {
        let i = self
            .row_blocks
            .partition_point(|r| r.end <= v)
            .min(self.grid.r - 1);
        let j = self
            .col_blocks
            .partition_point(|r| r.end <= u)
            .min(self.grid.c - 1);
        self.grid.rank_of(i, j)
    }
}

/// Compute the 2D layout for `p` ranks over `o`.
pub fn layout(o: &Oriented, p: usize) -> TileLayout {
    let grid = grid_for(p);
    let n = o.num_nodes();
    let goff = o.offsets();
    // Row cost: oriented out-degree (+1 so empty-degree prefixes still
    // spread rows); column cost: oriented in-degree (+1 likewise).
    let mut row_cost = vec![0u64; n];
    for (v, w) in row_cost.iter_mut().enumerate() {
        *w = goff[v + 1] - goff[v] + 1;
    }
    let mut col_cost = vec![1u64; n];
    for &u in o.targets() {
        col_cost[u as usize] += 1;
    }
    TileLayout {
        grid,
        procs: p,
        row_blocks: balanced_ranges(&prefix_sums(&row_cost), grid.r),
        col_blocks: balanced_ranges(&prefix_sums(&col_cost), grid.c),
    }
}

/// Arithmetic size prediction for one tile — the quantity each tile
/// rank's measured [`OwnedPartition::resident_bytes`] must equal.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TileSize {
    /// Rows stored (`|R_i|`; 0 for remainder ranks).
    pub rows: u64,
    /// Oriented edges in the tile (`|E ∩ R_i×C_j|`).
    pub edges: u64,
}

impl TileSize {
    /// Resident bytes of the materialized tile: `(rows+1)·8` offset
    /// entries + `edges·4` target ids (remainder ranks keep the single
    /// empty-offset entry: 8 bytes).
    pub fn bytes(&self) -> u64 {
        (self.rows + 1) * 8 + self.edges * 4
    }
}

/// Per-rank tile sizes in rank order (`procs` entries; remainder ranks
/// get `rows == edges == 0`). One O(m) sweep.
pub fn tile_sizes(o: &Oriented, layout: &TileLayout) -> Vec<TileSize> {
    let grid = layout.grid;
    let mut sizes = vec![TileSize { rows: 0, edges: 0 }; layout.procs];
    let cols = OwnerTable::new(&layout.col_blocks);
    for (i, rb) in layout.row_blocks.iter().enumerate() {
        for j in 0..grid.c {
            sizes[grid.rank_of(i, j)].rows = rb.len() as u64;
        }
        for v in rb.clone() {
            for (j, run) in cols.runs(o.nbrs(v)) {
                sizes[grid.rank_of(i, j as usize)].edges += run.len() as u64;
            }
        }
    }
    sizes
}

/// Materialize every rank's tile (active grid tiles + empty remainder
/// tiles), fanned out over the [`crate::par`] scoped-thread helpers like
/// the 1D extractions — one tile per work item, bit-identical at every
/// thread count.
pub fn extract_tiles(
    o: &Oriented,
    layout: &TileLayout,
    hub: HubThreshold,
) -> Vec<OwnedPartition> {
    let owners = OwnerTable::new(&layout.row_blocks);
    let p = layout.procs;
    let n = o.num_nodes() as u32;
    let t = crate::par::clamp_threads(crate::par::default_threads(), p, 1);
    crate::par::for_ranges(p, t, |_, idx| {
        idx.map(|rank| match layout.grid.coords(rank) {
            Some((i, j)) => extract_tile(o, layout, i, j, hub, owners.clone()),
            // Remainder rank: an empty tile (one offset entry, no rows).
            None => OwnedPartition::from_rows(n..n, vec![0], Vec::new(), hub, owners.clone()),
        })
        .collect::<Vec<_>>()
    })
    .into_iter()
    .flatten()
    .collect()
}

fn extract_tile(
    o: &Oriented,
    layout: &TileLayout,
    i: usize,
    j: usize,
    hub: HubThreshold,
    owners: OwnerTable,
) -> OwnedPartition {
    let rb = layout.row_blocks[i].clone();
    let cb = layout.col_blocks[j].clone();
    let mut offsets = Vec::with_capacity(rb.len() + 1);
    offsets.push(0u64);
    let mut targets = Vec::new();
    for v in rb.clone() {
        // The column block is an id-interval, so the tile's piece of N_v
        // is one contiguous subslice — the same slice discipline as the
        // 1D extraction, per column.
        let nv = o.nbrs(v);
        let lo = nv.partition_point(|&u| u < cb.start);
        let hi = nv.partition_point(|&u| u < cb.end);
        targets.extend_from_slice(&nv[lo..hi]);
        offsets.push(targets.len() as u64);
    }
    OwnedPartition::from_rows(rb, offsets, targets, hub, owners)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::rng::Rng;

    #[test]
    fn grid_factorization_pins() {
        for (p, r, c) in [
            (1, 1, 1),
            (2, 1, 2),
            (3, 1, 3),
            (4, 2, 2),
            (5, 2, 2),
            (6, 2, 3),
            (8, 2, 4),
            (9, 3, 3),
            (12, 3, 4),
            (13, 3, 4),
            (16, 4, 4),
        ] {
            let g = grid_for(p);
            assert_eq!((g.r, g.c), (r, c), "P={p}");
            assert!(g.active() <= p);
        }
    }

    #[test]
    fn grid_coords_round_trip() {
        let g = grid_for(13);
        assert_eq!(g.active(), 12);
        for rank in 0..12 {
            let (i, j) = g.coords(rank).unwrap();
            assert_eq!(g.rank_of(i, j), rank);
        }
        assert_eq!(g.coords(12), None, "remainder rank");
    }

    fn test_oriented(n: usize, d: usize, seed: u64) -> Oriented {
        let g = crate::gen::pa::preferential_attachment(n, d, &mut Rng::seeded(seed));
        Oriented::from_graph(&g)
    }

    #[test]
    fn blocks_tile_the_id_space() {
        let o = test_oriented(800, 6, 3);
        for p in [1, 2, 4, 6, 8, 9, 16] {
            let l = layout(&o, p);
            for blocks in [&l.row_blocks, &l.col_blocks] {
                assert_eq!(blocks[0].start, 0);
                assert_eq!(blocks.last().unwrap().end, o.num_nodes() as u32);
                for w in blocks.windows(2) {
                    assert_eq!(w[0].end, w[1].start);
                }
            }
        }
    }

    #[test]
    fn tile_cover_is_exact() {
        // Every oriented edge lands in exactly one tile; the union of the
        // tiles is the orientation; measured bytes == TileSize prediction.
        let o = test_oriented(600, 8, 11);
        let mut full: Vec<(u32, u32)> = Vec::new();
        for v in 0..o.num_nodes() as u32 {
            full.extend(o.nbrs(v).iter().map(|&u| (v, u)));
        }
        full.sort_unstable();
        for p in [1, 2, 4, 6, 8, 9, 16] {
            let l = layout(&o, p);
            let tiles = extract_tiles(&o, &l, HubThreshold::Auto);
            let sizes = tile_sizes(&o, &l);
            assert_eq!(tiles.len(), p);
            assert_eq!(sizes.len(), p);
            let mut union: Vec<(u32, u32)> = Vec::new();
            for (rank, (tile, size)) in tiles.iter().zip(&sizes).enumerate() {
                assert_eq!(tile.resident_bytes(), size.bytes(), "P={p} rank={rank}");
                assert_eq!(tile.num_edges(), size.edges);
                for v in tile.range() {
                    for &u in tile.nbrs(v) {
                        assert_eq!(l.tile_of(v, u), rank, "edge ({v},{u})");
                        union.push((v, u));
                    }
                }
            }
            union.sort_unstable();
            assert_eq!(union, full, "P={p}: tiles tile E exactly");
        }
    }

    #[test]
    fn shuffle_is_deterministic_and_preserves_the_count() {
        let o = test_oriented(900, 8, 17);
        let a = shuffled(&o);
        let b = shuffled(&o);
        assert_eq!(a.offsets(), b.offsets());
        assert_eq!(a.targets(), b.targets());
        assert_eq!(a.num_edges(), o.num_edges());
        assert_eq!(
            crate::seq::node_iterator::count(&a),
            crate::seq::node_iterator::count(&o)
        );
    }

    #[test]
    fn shuffle_balances_tiles_on_skewed_graphs() {
        // Degree-ordered PA piles hub–hub edges into the corner tile
        // (max tile grows ≈ √P over the mean); over shuffled ids every
        // block is a uniform vertex sample, so the max tile must stay
        // near the mean — the property the bench-comm traffic gate
        // (bytes falling as √P) rests on.
        let o = test_oriented(3000, 16, 9);
        let sh = shuffled(&o);
        for p in [4, 9, 16] {
            let l = layout(&sh, p);
            let sizes = tile_sizes(&sh, &l);
            let max = sizes.iter().map(|s| s.edges).max().unwrap();
            let avg = sh.num_edges() / l.grid.active() as u64;
            assert!(
                max as f64 <= avg as f64 * 1.35,
                "P={p}: max tile {max} vs avg {avg}"
            );
        }
    }

    #[test]
    fn remainder_ranks_hold_empty_tiles() {
        let o = test_oriented(300, 5, 4);
        let l = layout(&o, 5); // 2×2 grid + 1 remainder rank
        assert_eq!(l.grid.active(), 4);
        let tiles = extract_tiles(&o, &l, HubThreshold::Auto);
        assert_eq!(tiles.len(), 5);
        assert_eq!(tiles[4].num_rows(), 0);
        assert_eq!(tiles[4].num_edges(), 0);
        assert_eq!(tiles[4].resident_bytes(), 8);
        assert_eq!(tile_sizes(&o, &l)[4].bytes(), 8);
    }

    #[test]
    fn extraction_identical_at_any_thread_count() {
        let o = test_oriented(1200, 7, 21);
        let l = layout(&o, 6);
        let prev = crate::par::default_threads();
        crate::par::set_default_threads(1);
        let serial = extract_tiles(&o, &l, HubThreshold::Auto);
        crate::par::set_default_threads(4);
        let par = extract_tiles(&o, &l, HubThreshold::Auto);
        crate::par::set_default_threads(prev);
        for (a, b) in serial.iter().zip(&par) {
            assert_eq!(a.range(), b.range());
            assert_eq!(a.num_edges(), b.num_edges());
            assert_eq!(a.resident_bytes(), b.resident_bytes());
            assert_eq!(a.accel_bytes(), b.accel_bytes());
        }
    }
}
