//! Cost-estimation functions `f(v)` for balanced partitioning (§IV-B/F, §V).
//!
//! The partitioners and the dynamic load balancer all need an estimate of
//! the cost of counting triangles attributed to node `v`. The paper uses:
//!
//! * `f(v) = 1` and `f(v) = d_v` — the cheap §V task-sizing functions;
//! * `f(v) = Σ_{u∈N_v}(d̂_v + d̂_u)` — PATRIC's experimentally-best
//!   estimator [21], which models the cost of the *local* loop;
//! * `f(v) = Σ_{u∈𝒩_v−N_v}(d̂_v + d̂_u)` — this paper's §IV-F estimator,
//!   which attributes to `v` the cost of every intersection *executed on
//!   v's owner* under the surrogate scheme (case analysis in §IV-F);
//! * `f(v) = Σ_{u∈N_v} hybrid_cost(v, u)` — the representation-aware
//!   estimator: same attribution as PATRIC's, but charging the `adj/`
//!   dispatch's actual kernel per pair ([`Oriented::intersect_cost`]), so
//!   partitions stay balanced after hub bitmaps make hub work cheap.

use crate::config::CostFn;
use crate::graph::ordering::Oriented;
use crate::VertexId;

/// Evaluate a cost function for every node. O(m).
pub fn cost_vector(o: &Oriented, f: CostFn) -> Vec<u64> {
    let n = o.num_nodes();
    match f {
        CostFn::Unit => vec![1; n],
        CostFn::Degree => (0..n as VertexId).map(|v| o.degree(v) as u64).collect(),
        CostFn::PatricBest => {
            let mut c = vec![0u64; n];
            for v in 0..n as VertexId {
                let dv = o.effective_degree(v) as u64;
                c[v as usize] = o
                    .nbrs(v)
                    .iter()
                    .map(|&u| dv + o.effective_degree(u) as u64)
                    .sum();
            }
            c
        }
        CostFn::SurrogateNew => {
            // u ∈ 𝒩_v − N_v ⇔ v ∈ N_u: walk oriented edges u→v and charge v.
            let mut c = vec![0u64; n];
            for u in 0..n as VertexId {
                let du = o.effective_degree(u) as u64;
                for &v in o.nbrs(u) {
                    c[v as usize] += du + o.effective_degree(v) as u64;
                }
            }
            c
        }
        CostFn::Hybrid => (0..n as VertexId)
            .map(|v| o.nbrs(v).iter().map(|&u| o.intersect_cost(v, u)).sum())
            .collect(),
    }
}

/// Exclusive prefix sums of a cost vector: `prefix[i] = Σ_{v<i} cost[v]`,
/// length `n+1`. Every boundary search in the partitioners and the task
/// splitter runs on this.
pub fn prefix_sums(costs: &[u64]) -> Vec<u64> {
    let mut p = Vec::with_capacity(costs.len() + 1);
    p.push(0);
    let mut acc = 0u64;
    for &c in costs {
        acc += c;
        p.push(acc);
    }
    p
}

/// Cost of range `[lo, hi)` from prefix sums.
#[inline]
pub fn range_cost(prefix: &[u64], lo: usize, hi: usize) -> u64 {
    prefix[hi] - prefix[lo]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::classic;
    use crate::graph::ordering::Oriented;

    #[test]
    fn unit_and_degree() {
        let g = classic::star(4);
        let o = Oriented::from_graph(&g);
        assert_eq!(cost_vector(&o, CostFn::Unit), vec![1; 5]);
        assert_eq!(cost_vector(&o, CostFn::Degree), vec![4, 1, 1, 1, 1]);
    }

    #[test]
    fn patric_vs_new_total_identity() {
        // Both estimators sum the same per-edge terms (d̂_v + d̂_u), just
        // attributed to different endpoints — totals must be equal.
        let g = classic::karate();
        let o = Oriented::from_graph(&g);
        let a: u64 = cost_vector(&o, CostFn::PatricBest).iter().sum();
        let b: u64 = cost_vector(&o, CostFn::SurrogateNew).iter().sum();
        assert_eq!(a, b);
    }

    #[test]
    fn new_estimator_charges_receivers() {
        // Star: hub (high degree) is the ≺-top; every leaf's single oriented
        // edge points at the hub, so the surrogate intersections run on the
        // hub's owner → all cost lands on the hub.
        let g = classic::star(6);
        let o = Oriented::from_graph(&g);
        let c = cost_vector(&o, CostFn::SurrogateNew);
        assert!(c[0] > 0);
        assert!(c[1..].iter().all(|&x| x == 0), "{c:?}");
        // PATRIC's estimator instead charges the leaves (senders).
        let p = cost_vector(&o, CostFn::PatricBest);
        assert_eq!(p[0], 0);
        assert!(p[1..].iter().all(|&x| x > 0), "{p:?}");
    }

    #[test]
    fn hybrid_estimator_charges_the_dispatch_not_the_merge() {
        use crate::adj::HubThreshold;
        let g = classic::complete(12);
        let o = Oriented::from_graph_with(&g, HubThreshold::Fixed(4));
        let hybrid = cost_vector(&o, CostFn::Hybrid);
        // Per node it is exactly the true hybrid work measure...
        for v in 0..12u32 {
            assert_eq!(
                hybrid[v as usize],
                crate::seq::node_iterator::node_work_true(&o, v),
                "node {v}"
            );
        }
        // ...and on a hub-heavy graph strictly below the merge-model
        // estimator (word-AND collapses K₁₂ hub pairs to ~1 step each).
        let patric: u64 = cost_vector(&o, CostFn::PatricBest).iter().sum();
        assert!(hybrid.iter().sum::<u64>() < patric);
    }

    #[test]
    fn prefix_sum_and_range_cost() {
        let p = prefix_sums(&[3, 1, 4, 1, 5]);
        assert_eq!(p, vec![0, 3, 4, 8, 9, 14]);
        assert_eq!(range_cost(&p, 1, 4), 6);
        assert_eq!(range_cost(&p, 0, 5), 14);
        assert_eq!(range_cost(&p, 2, 2), 0);
    }

    #[test]
    fn new_estimator_matches_surrogate_work_definition() {
        // f(v) must equal the Σ over u ∈ 𝒩_v−N_v of (d̂_v + d̂_u), computed
        // directly from the unoriented graph.
        let g = classic::karate();
        let o = Oriented::from_graph(&g);
        let c = cost_vector(&o, CostFn::SurrogateNew);
        for v in 0..34u32 {
            let mut expect = 0u64;
            for &u in g.neighbors(v) {
                // u ∈ 𝒩_v − N_v ⇔ u ≺ v
                if o.precedes(u, v) {
                    expect += o.effective_degree(v) as u64 + o.effective_degree(u) as u64;
                }
            }
            assert_eq!(c[v as usize], expect, "node {v}");
        }
    }
}
