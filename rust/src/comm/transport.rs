//! The transport abstraction under [`crate::comm::threads::Comm`].
//!
//! The paper's algorithms are message-passing *protocols*; which fabric
//! carries the messages is an implementation detail the protocol must not
//! depend on. This module pins that contract as the [`Transport`] trait and
//! provides the production implementation, [`ChannelTransport`]: one
//! unbounded mpsc channel per rank plus a shared barrier/reduce cell —
//! exactly the seed's `comm::threads` internals, extracted unchanged.
//!
//! The second implementation is `testkit::sim::VirtualEndpoint`: a seeded,
//! deterministically scheduled fabric with virtual time, adversarial
//! delivery orders and injectable faults, used by the conformance suite to
//! pin protocol correctness under schedules the OS scheduler would produce
//! once a year at 3am (DESIGN.md §10).
//!
//! Semantics every implementation must honor (the MPI subset the
//! algorithms assume):
//!
//! * **Non-overtaking per (src, dst) pair**: two messages from the same
//!   sender to the same receiver are delivered in send order. Messages
//!   from *different* senders may interleave arbitrarily.
//! * `send` is asynchronous with unbounded buffering (MPI eager mode).
//! * `barrier`/`reduce_sum` are collectives over all ranks; they are
//!   fallible because a fabric may detect that completion has become
//!   impossible (a dead rank) instead of hanging.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Barrier, Mutex};

use crate::comm::threads::recv_guard;
use crate::error::{Error, Result};

/// Messages must declare their wire size so the metrics layer can account
/// bytes the way the paper reasons about them (neighbor-list words).
pub trait Payload: Send + 'static {
    /// Serialized size in bytes if this were on an MPI wire.
    fn size_bytes(&self) -> u64;
}

impl Payload for Vec<u32> {
    fn size_bytes(&self) -> u64 {
        (self.len() * 4) as u64
    }
}

impl Payload for u64 {
    fn size_bytes(&self) -> u64 {
        8
    }
}

/// Wire envelope: sender rank, control-plane flag, payload. The flag lets
/// the receive side account control traffic apart from data (the send side
/// already does), keeping `CommMetrics` symmetric.
pub struct Envelope<M> {
    pub src: usize,
    pub control: bool,
    pub msg: M,
}

/// A rank's endpoint into some message fabric. `Comm` stores one per rank
/// (inline, as an enum variant) and dispatches each call statically per
/// variant, so every counting path runs unmodified over any implementation
/// with no vtable on the channel hot path. The trait is kept object-safe
/// anyway so external harnesses may box their own fabrics.
pub trait Transport<M: Payload>: Send {
    /// This rank's id in `0..size`.
    fn rank(&self) -> usize;

    /// Number of ranks `P`.
    fn size(&self) -> usize;

    /// Called once by the launcher before the rank program runs. Fabrics
    /// that gate execution (the virtual scheduler) block here until the
    /// rank is scheduled; the channel fabric starts immediately.
    fn start(&mut self) {}

    /// Asynchronous point-to-point send (self-send allowed).
    fn send(&mut self, dst: usize, env: Envelope<M>) -> Result<()>;

    /// Non-blocking receive.
    fn try_recv(&mut self) -> Option<Envelope<M>>;

    /// Blocking receive. Must not hang forever: implementations bound the
    /// wait (wall-clock guard on channels, virtual-time deadlock detection
    /// on the simulator) and surface it as an `Err`.
    fn recv(&mut self) -> Result<Envelope<M>>;

    /// Synchronize all ranks (MPI_Barrier).
    fn barrier(&mut self) -> Result<()>;

    /// Sum-reduce a u64 across all ranks; everyone receives the total
    /// (MPI_Allreduce(SUM)).
    fn reduce_sum(&mut self, value: u64) -> Result<u64>;

    /// Deterministic virtual-clock reading, in ticks, for fabrics that
    /// schedule under one; `None` on wall-clock fabrics (the default).
    /// The obs layer uses it to stamp phase spans and `recv_wait` in
    /// virtual time, so adversarial schedules replay to bit-identical
    /// timelines (DESIGN.md §11). Only meaningful while the calling rank
    /// is the scheduled one — which is always true from inside a rank
    /// program on the simulator.
    fn virtual_now(&self) -> Option<u64> {
        None
    }
}

/// State shared by all ranks of one channel-backed cluster.
struct ChannelShared {
    barrier: Barrier,
    reduce_cells: Mutex<Vec<u64>>,
    reduce_acc: AtomicU64,
}

/// The production fabric: typed mpsc channels + `std::sync::Barrier`,
/// exactly the seed implementation. Zero new indirection on the hot path —
/// `Comm` holds it inline (enum variant, not a box).
pub struct ChannelTransport<M: Payload> {
    rank: usize,
    size: usize,
    senders: Vec<Sender<Envelope<M>>>,
    receiver: Receiver<Envelope<M>>,
    shared: Arc<ChannelShared>,
}

/// Build the `P` connected channel endpoints of a cluster, indexed by rank.
pub fn channel_fabric<M: Payload>(p: usize) -> Vec<ChannelTransport<M>> {
    let mut senders = Vec::with_capacity(p);
    let mut receivers = Vec::with_capacity(p);
    for _ in 0..p {
        let (tx, rx) = std::sync::mpsc::channel();
        senders.push(tx);
        receivers.push(rx);
    }
    let shared = Arc::new(ChannelShared {
        barrier: Barrier::new(p),
        reduce_cells: Mutex::new(vec![0; p]),
        reduce_acc: AtomicU64::new(0),
    });
    receivers
        .into_iter()
        .enumerate()
        .map(|(rank, receiver)| ChannelTransport {
            rank,
            size: p,
            senders: senders.clone(),
            receiver,
            shared: shared.clone(),
        })
        .collect()
}

impl<M: Payload> Transport<M> for ChannelTransport<M> {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.size
    }

    fn send(&mut self, dst: usize, env: Envelope<M>) -> Result<()> {
        self.senders[dst]
            .send(env)
            .map_err(|_| Error::Cluster(format!("rank {} send to dead rank {dst}", self.rank)))
    }

    fn try_recv(&mut self) -> Option<Envelope<M>> {
        self.receiver.try_recv().ok()
    }

    fn recv(&mut self) -> Result<Envelope<M>> {
        let guard = recv_guard();
        match self.receiver.recv_timeout(guard) {
            Ok(env) => Ok(env),
            Err(RecvTimeoutError::Timeout) => Err(Error::Cluster(format!(
                "rank {} recv timed out after {guard:?} (protocol deadlock?)",
                self.rank
            ))),
            Err(RecvTimeoutError::Disconnected) => {
                Err(Error::Cluster(format!("rank {} peers disconnected", self.rank)))
            }
        }
    }

    fn barrier(&mut self) -> Result<()> {
        self.shared.barrier.wait();
        Ok(())
    }

    /// Internally: write cell → barrier → rank 0 sums → barrier → read.
    fn reduce_sum(&mut self, value: u64) -> Result<u64> {
        {
            let mut cells = self.shared.reduce_cells.lock().unwrap();
            cells[self.rank] = value;
        }
        self.shared.barrier.wait();
        if self.rank == 0 {
            let cells = self.shared.reduce_cells.lock().unwrap();
            let sum = cells.iter().sum();
            self.shared.reduce_acc.store(sum, Ordering::SeqCst);
        }
        self.shared.barrier.wait();
        Ok(self.shared.reduce_acc.load(Ordering::SeqCst))
    }
}
