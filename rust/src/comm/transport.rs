//! The transport abstraction under [`crate::comm::threads::Comm`].
//!
//! The paper's algorithms are message-passing *protocols*; which fabric
//! carries the messages is an implementation detail the protocol must not
//! depend on. This module pins that contract as the [`Transport`] trait and
//! provides the production implementation, [`ChannelTransport`]: one
//! unbounded mpsc channel per rank plus a shared barrier/reduce cell —
//! exactly the seed's `comm::threads` internals, extracted unchanged.
//!
//! The second implementation is `testkit::sim::VirtualEndpoint`: a seeded,
//! deterministically scheduled fabric with virtual time, adversarial
//! delivery orders and injectable faults, used by the conformance suite to
//! pin protocol correctness under schedules the OS scheduler would produce
//! once a year at 3am (DESIGN.md §10).
//!
//! Semantics every implementation must honor (the MPI subset the
//! algorithms assume):
//!
//! * **Non-overtaking per (src, dst) pair**: two messages from the same
//!   sender to the same receiver are delivered in send order. Messages
//!   from *different* senders may interleave arbitrarily.
//! * `send` is asynchronous with unbounded buffering (MPI eager mode).
//! * `barrier`/`reduce_sum` are collectives over all ranks; they are
//!   fallible because a fabric may detect that completion has become
//!   impossible (a dead rank) instead of hanging.

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Barrier, Mutex};
use std::time::{Duration, Instant};

use crate::comm::threads::recv_guard;
use crate::error::{Error, Result};

/// Binary wire codec for values that cross a real socket (`comm::tcp`).
/// Little-endian, length-prefixed sequences, no self-description — both
/// ends run the same build, and the TCP handshake pins a wire version.
///
/// Decoding is *total*: every malformed input returns [`Error::Comm`]
/// (never a panic, never unbounded allocation — length prefixes are
/// validated against the bytes actually present before any `Vec` is
/// reserved), which is what the wire-corruption property tests pin.
pub trait Wire: Sized {
    /// Append this value's encoding to `out`.
    fn write_to(&self, out: &mut Vec<u8>);

    /// Decode one value from the cursor, consuming exactly what
    /// [`Wire::write_to`] produced.
    fn read_from(r: &mut WireReader<'_>) -> Result<Self>;

    /// Encode into a fresh buffer (convenience for frame assembly).
    fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        self.write_to(&mut out);
        out
    }

    /// Decode a value that must occupy the *entire* buffer — trailing
    /// bytes are a framing error ([`Error::Comm`]).
    fn from_bytes(buf: &[u8]) -> Result<Self> {
        let mut r = WireReader::new(buf);
        let v = Self::read_from(&mut r)?;
        r.finish()?;
        Ok(v)
    }
}

/// Bounds-checked little-endian cursor over a received byte buffer. Every
/// overrun is an [`Error::Comm`] naming the shortfall.
pub struct WireReader<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> WireReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        WireReader { buf, at: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.at
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(Error::Comm(format!(
                "truncated frame: wanted {n} bytes at offset {}, {} left",
                self.at,
                self.remaining()
            )));
        }
        let s = &self.buf[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// A `u64` length prefix for `elem_bytes`-wide elements, validated
    /// against the bytes actually remaining — a corrupt prefix fails here
    /// instead of driving a multi-gigabyte `Vec::with_capacity`.
    pub fn len_prefix(&mut self, elem_bytes: usize) -> Result<usize> {
        let n = self.u64()?;
        let need = (n as usize).checked_mul(elem_bytes).unwrap_or(usize::MAX);
        if n > self.remaining() as u64 || need > self.remaining() {
            return Err(Error::Comm(format!(
                "length prefix {n} exceeds payload ({} bytes left)",
                self.remaining()
            )));
        }
        Ok(n as usize)
    }

    /// Assert the buffer is fully consumed (exact framing).
    pub fn finish(&self) -> Result<()> {
        if self.remaining() != 0 {
            return Err(Error::Comm(format!(
                "{} trailing bytes after decoded value",
                self.remaining()
            )));
        }
        Ok(())
    }
}

macro_rules! wire_le_int {
    ($($t:ty => $read:ident),*) => {$(
        impl Wire for $t {
            fn write_to(&self, out: &mut Vec<u8>) {
                out.extend_from_slice(&self.to_le_bytes());
            }
            #[allow(clippy::useless_conversion, clippy::unnecessary_cast)]
            fn read_from(r: &mut WireReader<'_>) -> Result<Self> {
                r.$read().map(|v| v as $t)
            }
        }
    )*};
}
wire_le_int!(u32 => u32, u64 => u64, i64 => u64);

impl Wire for () {
    fn write_to(&self, _out: &mut Vec<u8>) {}
    fn read_from(_r: &mut WireReader<'_>) -> Result<Self> {
        Ok(())
    }
}

impl Wire for bool {
    fn write_to(&self, out: &mut Vec<u8>) {
        out.push(*self as u8);
    }
    fn read_from(r: &mut WireReader<'_>) -> Result<Self> {
        match r.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            b => Err(Error::Comm(format!("invalid bool byte {b}"))),
        }
    }
}

impl Wire for Vec<u32> {
    fn write_to(&self, out: &mut Vec<u8>) {
        (self.len() as u64).write_to(out);
        for v in self {
            v.write_to(out);
        }
    }
    fn read_from(r: &mut WireReader<'_>) -> Result<Self> {
        let n = r.len_prefix(4)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(r.u32()?);
        }
        Ok(v)
    }
}

impl Wire for Vec<u64> {
    fn write_to(&self, out: &mut Vec<u8>) {
        (self.len() as u64).write_to(out);
        for v in self {
            v.write_to(out);
        }
    }
    fn read_from(r: &mut WireReader<'_>) -> Result<Self> {
        let n = r.len_prefix(8)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(r.u64()?);
        }
        Ok(v)
    }
}

impl Wire for std::sync::Arc<[u32]> {
    fn write_to(&self, out: &mut Vec<u8>) {
        (self.len() as u64).write_to(out);
        for v in self.iter() {
            v.write_to(out);
        }
    }
    fn read_from(r: &mut WireReader<'_>) -> Result<Self> {
        Ok(Vec::<u32>::read_from(r)?.into())
    }
}

impl<T: Wire> Wire for Option<T> {
    fn write_to(&self, out: &mut Vec<u8>) {
        match self {
            None => out.push(0),
            Some(v) => {
                out.push(1);
                v.write_to(out);
            }
        }
    }
    fn read_from(r: &mut WireReader<'_>) -> Result<Self> {
        match r.u8()? {
            0 => Ok(None),
            1 => Ok(Some(T::read_from(r)?)),
            b => Err(Error::Comm(format!("invalid option byte {b}"))),
        }
    }
}

impl<A: Wire, B: Wire> Wire for (A, B) {
    fn write_to(&self, out: &mut Vec<u8>) {
        self.0.write_to(out);
        self.1.write_to(out);
    }
    fn read_from(r: &mut WireReader<'_>) -> Result<Self> {
        Ok((A::read_from(r)?, B::read_from(r)?))
    }
}

/// Durations travel as whole microseconds — the resolution every clock
/// domain in the crate already reports in.
impl Wire for Duration {
    fn write_to(&self, out: &mut Vec<u8>) {
        (self.as_micros() as u64).write_to(out);
    }
    fn read_from(r: &mut WireReader<'_>) -> Result<Self> {
        Ok(Duration::from_micros(r.u64()?))
    }
}

impl Wire for String {
    fn write_to(&self, out: &mut Vec<u8>) {
        (self.len() as u64).write_to(out);
        out.extend_from_slice(self.as_bytes());
    }
    fn read_from(r: &mut WireReader<'_>) -> Result<Self> {
        let n = r.len_prefix(1)?;
        let bytes = r.take(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| Error::Comm("invalid utf-8 in wire string".into()))
    }
}

/// Messages must declare their wire size so the metrics layer can account
/// bytes the way the paper reasons about them (neighbor-list words), and
/// must be wire-codable ([`Wire`]) so the socket fabric (`comm::tcp`) can
/// carry them. `size_bytes` stays the single byte-accounting truth:
/// `CommMetrics::bytes_sent` counts declared sizes on every fabric, and
/// the framing the TCP encoder adds on top is reported separately
/// (`CommMetrics::wire_overhead_bytes`).
pub trait Payload: Wire + Send + 'static {
    /// Serialized size in bytes if this were on an MPI wire.
    fn size_bytes(&self) -> u64;
}

impl Payload for Vec<u32> {
    fn size_bytes(&self) -> u64 {
        (self.len() * 4) as u64
    }
}

impl Payload for u64 {
    fn size_bytes(&self) -> u64 {
        8
    }
}

/// Wire envelope: sender rank, control-plane flag, payload. The flag lets
/// the receive side account control traffic apart from data (the send side
/// already does), keeping `CommMetrics` symmetric.
pub struct Envelope<M> {
    pub src: usize,
    pub control: bool,
    pub msg: M,
}

/// What a fabric can say about a peer when asked (`ft/` supervision). The
/// classification rides on the *liveness board* every fabric maintains —
/// a heartbeat tag class published on each transport op, not extra wire
/// messages — so a supervisor can distinguish "slow" (recent heartbeat,
/// keep waiting / retry) from "dead" (failed or retired, re-execute).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Liveness {
    /// Peer heart-beat recently; a missing reply means in-flight or queued.
    Alive,
    /// Peer still running but its last heartbeat is stale — a straggler.
    Slow,
    /// Peer failed, was killed by a fault plan, or already retired.
    Dead,
}

/// Bounded-retry schedule for request/reply protocols (`ft/` transport
/// hardening). Deadlines grow by a deterministic exponential backoff so a
/// replayed schedule retries at identical (virtual) times:
/// `deadline(attempt) = base · backoff^attempt`.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// First-attempt receive deadline.
    pub base: Duration,
    /// Retransmissions allowed after the first deadline expiry.
    pub max_retries: u32,
    /// Deadline multiplier per retry (≥ 1).
    pub backoff: u32,
}

impl Default for RetryPolicy {
    /// Derived from the configured [`recv_guard`] so there is one timeout
    /// knob: the total budget across all attempts stays within a small
    /// multiple of the guard (base = guard/4, 3 retries, ×2 backoff ⇒
    /// ≤ 3.75 × guard before a peer is presumed dead).
    fn default() -> Self {
        RetryPolicy { base: recv_guard() / 4, max_retries: 3, backoff: 2 }
    }
}

impl RetryPolicy {
    /// Deadline for the given 0-based attempt, saturating on overflow.
    pub fn deadline_for(&self, attempt: u32) -> Duration {
        let factor = self.backoff.saturating_pow(attempt.min(16));
        self.base.saturating_mul(factor.max(1))
    }
}

/// Per-rank run state on the liveness board.
pub(crate) const LIVE_RUNNING: u8 = 0;
pub(crate) const LIVE_DONE: u8 = 1;
pub(crate) const LIVE_FAILED: u8 = 2;

/// A rank's endpoint into some message fabric. `Comm` stores one per rank
/// (inline, as an enum variant) and dispatches each call statically per
/// variant, so every counting path runs unmodified over any implementation
/// with no vtable on the channel hot path. The trait is kept object-safe
/// anyway so external harnesses may box their own fabrics.
pub trait Transport<M: Payload>: Send {
    /// This rank's id in `0..size`.
    fn rank(&self) -> usize;

    /// Number of ranks `P`.
    fn size(&self) -> usize;

    /// Called once by the launcher before the rank program runs. Fabrics
    /// that gate execution (the virtual scheduler) block here until the
    /// rank is scheduled; the channel fabric starts immediately.
    fn start(&mut self) {}

    /// Asynchronous point-to-point send (self-send allowed).
    fn send(&mut self, dst: usize, env: Envelope<M>) -> Result<()>;

    /// Non-blocking receive.
    fn try_recv(&mut self) -> Option<Envelope<M>>;

    /// Blocking receive. Must not hang forever: implementations bound the
    /// wait (wall-clock guard on channels, virtual-time deadlock detection
    /// on the simulator) and surface it as an `Err`.
    fn recv(&mut self) -> Result<Envelope<M>>;

    /// Receive with an explicit deadline: `Ok(None)` when it expires with
    /// nothing delivered — the caller decides whether to retry (bounded,
    /// [`RetryPolicy`]) or escalate. The channel fabric waits `d` of wall
    /// time; the virtual fabric answers the deadline in *virtual time*
    /// (the scheduler wakes deadline-blocked ranks deterministically when
    /// no other progress is possible), so recovery schedules replay. The
    /// default routes through [`Transport::recv`] for fabrics without
    /// timers — correct, but it turns deadline expiry into that fabric's
    /// blocking-receive error.
    fn recv_deadline(&mut self, _d: Duration) -> Result<Option<Envelope<M>>> {
        self.recv().map(Some)
    }

    /// Classify a peer from the fabric's liveness board ([`Liveness`]):
    /// heartbeats are published on every transport op, and `stale_after`
    /// is the silence span after which a running peer reads as `Slow`.
    /// Fabrics without a board answer `Alive` (the conservative default:
    /// never presume a peer dead on no evidence).
    fn liveness(&self, _rank: usize, _stale_after: Duration) -> Liveness {
        Liveness::Alive
    }

    /// Called once by the launcher when the rank program returns, with
    /// its outcome — retires this rank on the liveness board so peers
    /// stop waiting on it.
    fn retire(&mut self, _ok: bool) {}

    /// Synchronize all ranks (MPI_Barrier).
    fn barrier(&mut self) -> Result<()>;

    /// Sum-reduce a u64 across all ranks; everyone receives the total
    /// (MPI_Allreduce(SUM)).
    fn reduce_sum(&mut self, value: u64) -> Result<u64>;

    /// Deterministic virtual-clock reading, in ticks, for fabrics that
    /// schedule under one; `None` on wall-clock fabrics (the default).
    /// The obs layer uses it to stamp phase spans and `recv_wait` in
    /// virtual time, so adversarial schedules replay to bit-identical
    /// timelines (DESIGN.md §11). Only meaningful while the calling rank
    /// is the scheduled one — which is always true from inside a rank
    /// program on the simulator.
    fn virtual_now(&self) -> Option<u64> {
        None
    }
}

/// State shared by all ranks of one channel-backed cluster: the
/// barrier/reduce cells plus the liveness board (`ft/` supervision) —
/// per-rank run state and last-heartbeat stamps, published lock-free on
/// every transport op.
struct ChannelShared {
    barrier: Barrier,
    reduce_cells: Mutex<Vec<u64>>,
    reduce_acc: AtomicU64,
    /// Per-rank [`LIVE_RUNNING`]/[`LIVE_DONE`]/[`LIVE_FAILED`].
    state: Vec<AtomicU8>,
    /// Per-rank µs-since-fabric-build of the last transport op.
    beat: Vec<AtomicU64>,
    /// Common epoch the heartbeat stamps are measured from.
    epoch: Instant,
}

/// The production fabric: typed mpsc channels + `std::sync::Barrier`,
/// exactly the seed implementation. Zero new indirection on the hot path —
/// `Comm` holds it inline (enum variant, not a box).
pub struct ChannelTransport<M: Payload> {
    rank: usize,
    size: usize,
    senders: Vec<Sender<Envelope<M>>>,
    receiver: Receiver<Envelope<M>>,
    shared: Arc<ChannelShared>,
}

/// Build the `P` connected channel endpoints of a cluster, indexed by rank.
pub fn channel_fabric<M: Payload>(p: usize) -> Vec<ChannelTransport<M>> {
    let mut senders = Vec::with_capacity(p);
    let mut receivers = Vec::with_capacity(p);
    for _ in 0..p {
        let (tx, rx) = std::sync::mpsc::channel();
        senders.push(tx);
        receivers.push(rx);
    }
    let shared = Arc::new(ChannelShared {
        barrier: Barrier::new(p),
        reduce_cells: Mutex::new(vec![0; p]),
        reduce_acc: AtomicU64::new(0),
        state: (0..p).map(|_| AtomicU8::new(LIVE_RUNNING)).collect(),
        beat: (0..p).map(|_| AtomicU64::new(0)).collect(),
        epoch: Instant::now(),
    });
    receivers
        .into_iter()
        .enumerate()
        .map(|(rank, receiver)| ChannelTransport {
            rank,
            size: p,
            senders: senders.clone(),
            receiver,
            shared: shared.clone(),
        })
        .collect()
}

impl<M: Payload> ChannelTransport<M> {
    /// Publish this rank's heartbeat (µs since the fabric epoch).
    #[inline]
    fn beat(&self) {
        self.shared.beat[self.rank]
            .store(self.shared.epoch.elapsed().as_micros() as u64, Ordering::Relaxed);
    }
}

impl<M: Payload> Transport<M> for ChannelTransport<M> {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.size
    }

    fn send(&mut self, dst: usize, env: Envelope<M>) -> Result<()> {
        self.beat();
        self.senders[dst]
            .send(env)
            .map_err(|_| Error::Cluster(format!("rank {} send to dead rank {dst}", self.rank)))
    }

    fn try_recv(&mut self) -> Option<Envelope<M>> {
        self.beat();
        self.receiver.try_recv().ok()
    }

    /// The blocking receive **is** the deadline receive at the configured
    /// [`recv_guard`] — one timeout path, not two: the guard env override
    /// and every ft/ deadline flow through [`Transport::recv_deadline`].
    fn recv(&mut self) -> Result<Envelope<M>> {
        let guard = recv_guard();
        match self.recv_deadline(guard)? {
            Some(env) => Ok(env),
            None => Err(Error::Cluster(format!(
                "rank {} recv timed out after {guard:?} (protocol deadlock?)",
                self.rank
            ))),
        }
    }

    fn recv_deadline(&mut self, d: Duration) -> Result<Option<Envelope<M>>> {
        self.beat();
        match self.receiver.recv_timeout(d) {
            Ok(env) => Ok(Some(env)),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => {
                Err(Error::Cluster(format!("rank {} peers disconnected", self.rank)))
            }
        }
    }

    fn liveness(&self, rank: usize, stale_after: Duration) -> Liveness {
        match self.shared.state[rank].load(Ordering::Relaxed) {
            LIVE_FAILED | LIVE_DONE => Liveness::Dead,
            _ => {
                let last = self.shared.beat[rank].load(Ordering::Relaxed);
                let now = self.shared.epoch.elapsed().as_micros() as u64;
                if now.saturating_sub(last) > stale_after.as_micros() as u64 {
                    Liveness::Slow
                } else {
                    Liveness::Alive
                }
            }
        }
    }

    fn retire(&mut self, ok: bool) {
        let s = if ok { LIVE_DONE } else { LIVE_FAILED };
        self.shared.state[self.rank].store(s, Ordering::Release);
    }

    fn barrier(&mut self) -> Result<()> {
        self.beat();
        self.shared.barrier.wait();
        Ok(())
    }

    /// Internally: write cell → barrier → rank 0 sums → barrier → read.
    fn reduce_sum(&mut self, value: u64) -> Result<u64> {
        self.beat();
        {
            let mut cells = self.shared.reduce_cells.lock().unwrap();
            cells[self.rank] = value;
        }
        self.shared.barrier.wait();
        if self.rank == 0 {
            let cells = self.shared.reduce_cells.lock().unwrap();
            let sum = cells.iter().sum();
            self.shared.reduce_acc.store(sum, Ordering::SeqCst);
        }
        self.shared.barrier.wait();
        Ok(self.shared.reduce_acc.load(Ordering::SeqCst))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip<T: Wire + PartialEq + std::fmt::Debug>(v: T) {
        let bytes = v.to_bytes();
        assert_eq!(T::from_bytes(&bytes).unwrap(), v);
    }

    #[test]
    fn wire_roundtrips() {
        roundtrip(0u32);
        roundtrip(u32::MAX);
        roundtrip(u64::MAX);
        roundtrip(-42i64);
        roundtrip(true);
        roundtrip(());
        roundtrip(vec![1u32, 2, 3]);
        roundtrip(Vec::<u32>::new());
        roundtrip(vec![u64::MAX, 0]);
        roundtrip(Some(7u64));
        roundtrip(Option::<u64>::None);
        roundtrip((3u32, vec![9u64]));
        roundtrip(String::from("hello wire"));
        roundtrip(Duration::from_micros(123_456));
        let a: std::sync::Arc<[u32]> = vec![5u32, 6].into();
        let b = std::sync::Arc::<[u32]>::from_bytes(&a.to_bytes()).unwrap();
        assert_eq!(&*a, &*b);
    }

    #[test]
    fn wire_decode_is_total_on_malformed_input() {
        // Truncation at every prefix of a valid encoding → Error::Comm.
        let full = (vec![1u32, 2, 3], 7u64).to_bytes();
        for cut in 0..full.len() {
            match <(Vec<u32>, u64)>::from_bytes(&full[..cut]) {
                Err(Error::Comm(_)) => {}
                other => panic!("cut={cut}: expected Comm error, got {other:?}"),
            }
        }
        // Trailing garbage is a framing error, not silently ignored.
        let mut padded = full.clone();
        padded.push(0xAB);
        assert!(matches!(<(Vec<u32>, u64)>::from_bytes(&padded), Err(Error::Comm(_))));
        // A length prefix far beyond the buffer must fail *before* any
        // allocation of that size.
        let mut huge = Vec::new();
        u64::MAX.write_to(&mut huge);
        assert!(matches!(Vec::<u32>::from_bytes(&huge), Err(Error::Comm(_))));
        // Invalid enum-ish bytes.
        assert!(matches!(bool::from_bytes(&[2]), Err(Error::Comm(_))));
        assert!(matches!(Option::<u64>::from_bytes(&[9]), Err(Error::Comm(_))));
        assert!(matches!(String::from_bytes(&{
            let mut b = Vec::new();
            2u64.write_to(&mut b);
            b.extend_from_slice(&[0xFF, 0xFE]);
            b
        }), Err(Error::Comm(_))));
    }
}
