//! The transport abstraction under [`crate::comm::threads::Comm`].
//!
//! The paper's algorithms are message-passing *protocols*; which fabric
//! carries the messages is an implementation detail the protocol must not
//! depend on. This module pins that contract as the [`Transport`] trait and
//! provides the production implementation, [`ChannelTransport`]: one
//! unbounded mpsc channel per rank plus a shared barrier/reduce cell —
//! exactly the seed's `comm::threads` internals, extracted unchanged.
//!
//! The second implementation is `testkit::sim::VirtualEndpoint`: a seeded,
//! deterministically scheduled fabric with virtual time, adversarial
//! delivery orders and injectable faults, used by the conformance suite to
//! pin protocol correctness under schedules the OS scheduler would produce
//! once a year at 3am (DESIGN.md §10).
//!
//! Semantics every implementation must honor (the MPI subset the
//! algorithms assume):
//!
//! * **Non-overtaking per (src, dst) pair**: two messages from the same
//!   sender to the same receiver are delivered in send order. Messages
//!   from *different* senders may interleave arbitrarily.
//! * `send` is asynchronous with unbounded buffering (MPI eager mode).
//! * `barrier`/`reduce_sum` are collectives over all ranks; they are
//!   fallible because a fabric may detect that completion has become
//!   impossible (a dead rank) instead of hanging.

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Barrier, Mutex};
use std::time::{Duration, Instant};

use crate::comm::threads::recv_guard;
use crate::error::{Error, Result};

/// Messages must declare their wire size so the metrics layer can account
/// bytes the way the paper reasons about them (neighbor-list words).
pub trait Payload: Send + 'static {
    /// Serialized size in bytes if this were on an MPI wire.
    fn size_bytes(&self) -> u64;
}

impl Payload for Vec<u32> {
    fn size_bytes(&self) -> u64 {
        (self.len() * 4) as u64
    }
}

impl Payload for u64 {
    fn size_bytes(&self) -> u64 {
        8
    }
}

/// Wire envelope: sender rank, control-plane flag, payload. The flag lets
/// the receive side account control traffic apart from data (the send side
/// already does), keeping `CommMetrics` symmetric.
pub struct Envelope<M> {
    pub src: usize,
    pub control: bool,
    pub msg: M,
}

/// What a fabric can say about a peer when asked (`ft/` supervision). The
/// classification rides on the *liveness board* every fabric maintains —
/// a heartbeat tag class published on each transport op, not extra wire
/// messages — so a supervisor can distinguish "slow" (recent heartbeat,
/// keep waiting / retry) from "dead" (failed or retired, re-execute).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Liveness {
    /// Peer heart-beat recently; a missing reply means in-flight or queued.
    Alive,
    /// Peer still running but its last heartbeat is stale — a straggler.
    Slow,
    /// Peer failed, was killed by a fault plan, or already retired.
    Dead,
}

/// Bounded-retry schedule for request/reply protocols (`ft/` transport
/// hardening). Deadlines grow by a deterministic exponential backoff so a
/// replayed schedule retries at identical (virtual) times:
/// `deadline(attempt) = base · backoff^attempt`.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// First-attempt receive deadline.
    pub base: Duration,
    /// Retransmissions allowed after the first deadline expiry.
    pub max_retries: u32,
    /// Deadline multiplier per retry (≥ 1).
    pub backoff: u32,
}

impl Default for RetryPolicy {
    /// Derived from the configured [`recv_guard`] so there is one timeout
    /// knob: the total budget across all attempts stays within a small
    /// multiple of the guard (base = guard/4, 3 retries, ×2 backoff ⇒
    /// ≤ 3.75 × guard before a peer is presumed dead).
    fn default() -> Self {
        RetryPolicy { base: recv_guard() / 4, max_retries: 3, backoff: 2 }
    }
}

impl RetryPolicy {
    /// Deadline for the given 0-based attempt, saturating on overflow.
    pub fn deadline_for(&self, attempt: u32) -> Duration {
        let factor = self.backoff.saturating_pow(attempt.min(16));
        self.base.saturating_mul(factor.max(1))
    }
}

/// Per-rank run state on the liveness board.
pub(crate) const LIVE_RUNNING: u8 = 0;
pub(crate) const LIVE_DONE: u8 = 1;
pub(crate) const LIVE_FAILED: u8 = 2;

/// A rank's endpoint into some message fabric. `Comm` stores one per rank
/// (inline, as an enum variant) and dispatches each call statically per
/// variant, so every counting path runs unmodified over any implementation
/// with no vtable on the channel hot path. The trait is kept object-safe
/// anyway so external harnesses may box their own fabrics.
pub trait Transport<M: Payload>: Send {
    /// This rank's id in `0..size`.
    fn rank(&self) -> usize;

    /// Number of ranks `P`.
    fn size(&self) -> usize;

    /// Called once by the launcher before the rank program runs. Fabrics
    /// that gate execution (the virtual scheduler) block here until the
    /// rank is scheduled; the channel fabric starts immediately.
    fn start(&mut self) {}

    /// Asynchronous point-to-point send (self-send allowed).
    fn send(&mut self, dst: usize, env: Envelope<M>) -> Result<()>;

    /// Non-blocking receive.
    fn try_recv(&mut self) -> Option<Envelope<M>>;

    /// Blocking receive. Must not hang forever: implementations bound the
    /// wait (wall-clock guard on channels, virtual-time deadlock detection
    /// on the simulator) and surface it as an `Err`.
    fn recv(&mut self) -> Result<Envelope<M>>;

    /// Receive with an explicit deadline: `Ok(None)` when it expires with
    /// nothing delivered — the caller decides whether to retry (bounded,
    /// [`RetryPolicy`]) or escalate. The channel fabric waits `d` of wall
    /// time; the virtual fabric answers the deadline in *virtual time*
    /// (the scheduler wakes deadline-blocked ranks deterministically when
    /// no other progress is possible), so recovery schedules replay. The
    /// default routes through [`Transport::recv`] for fabrics without
    /// timers — correct, but it turns deadline expiry into that fabric's
    /// blocking-receive error.
    fn recv_deadline(&mut self, _d: Duration) -> Result<Option<Envelope<M>>> {
        self.recv().map(Some)
    }

    /// Classify a peer from the fabric's liveness board ([`Liveness`]):
    /// heartbeats are published on every transport op, and `stale_after`
    /// is the silence span after which a running peer reads as `Slow`.
    /// Fabrics without a board answer `Alive` (the conservative default:
    /// never presume a peer dead on no evidence).
    fn liveness(&self, _rank: usize, _stale_after: Duration) -> Liveness {
        Liveness::Alive
    }

    /// Called once by the launcher when the rank program returns, with
    /// its outcome — retires this rank on the liveness board so peers
    /// stop waiting on it.
    fn retire(&mut self, _ok: bool) {}

    /// Synchronize all ranks (MPI_Barrier).
    fn barrier(&mut self) -> Result<()>;

    /// Sum-reduce a u64 across all ranks; everyone receives the total
    /// (MPI_Allreduce(SUM)).
    fn reduce_sum(&mut self, value: u64) -> Result<u64>;

    /// Deterministic virtual-clock reading, in ticks, for fabrics that
    /// schedule under one; `None` on wall-clock fabrics (the default).
    /// The obs layer uses it to stamp phase spans and `recv_wait` in
    /// virtual time, so adversarial schedules replay to bit-identical
    /// timelines (DESIGN.md §11). Only meaningful while the calling rank
    /// is the scheduled one — which is always true from inside a rank
    /// program on the simulator.
    fn virtual_now(&self) -> Option<u64> {
        None
    }
}

/// State shared by all ranks of one channel-backed cluster: the
/// barrier/reduce cells plus the liveness board (`ft/` supervision) —
/// per-rank run state and last-heartbeat stamps, published lock-free on
/// every transport op.
struct ChannelShared {
    barrier: Barrier,
    reduce_cells: Mutex<Vec<u64>>,
    reduce_acc: AtomicU64,
    /// Per-rank [`LIVE_RUNNING`]/[`LIVE_DONE`]/[`LIVE_FAILED`].
    state: Vec<AtomicU8>,
    /// Per-rank µs-since-fabric-build of the last transport op.
    beat: Vec<AtomicU64>,
    /// Common epoch the heartbeat stamps are measured from.
    epoch: Instant,
}

/// The production fabric: typed mpsc channels + `std::sync::Barrier`,
/// exactly the seed implementation. Zero new indirection on the hot path —
/// `Comm` holds it inline (enum variant, not a box).
pub struct ChannelTransport<M: Payload> {
    rank: usize,
    size: usize,
    senders: Vec<Sender<Envelope<M>>>,
    receiver: Receiver<Envelope<M>>,
    shared: Arc<ChannelShared>,
}

/// Build the `P` connected channel endpoints of a cluster, indexed by rank.
pub fn channel_fabric<M: Payload>(p: usize) -> Vec<ChannelTransport<M>> {
    let mut senders = Vec::with_capacity(p);
    let mut receivers = Vec::with_capacity(p);
    for _ in 0..p {
        let (tx, rx) = std::sync::mpsc::channel();
        senders.push(tx);
        receivers.push(rx);
    }
    let shared = Arc::new(ChannelShared {
        barrier: Barrier::new(p),
        reduce_cells: Mutex::new(vec![0; p]),
        reduce_acc: AtomicU64::new(0),
        state: (0..p).map(|_| AtomicU8::new(LIVE_RUNNING)).collect(),
        beat: (0..p).map(|_| AtomicU64::new(0)).collect(),
        epoch: Instant::now(),
    });
    receivers
        .into_iter()
        .enumerate()
        .map(|(rank, receiver)| ChannelTransport {
            rank,
            size: p,
            senders: senders.clone(),
            receiver,
            shared: shared.clone(),
        })
        .collect()
}

impl<M: Payload> ChannelTransport<M> {
    /// Publish this rank's heartbeat (µs since the fabric epoch).
    #[inline]
    fn beat(&self) {
        self.shared.beat[self.rank]
            .store(self.shared.epoch.elapsed().as_micros() as u64, Ordering::Relaxed);
    }
}

impl<M: Payload> Transport<M> for ChannelTransport<M> {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.size
    }

    fn send(&mut self, dst: usize, env: Envelope<M>) -> Result<()> {
        self.beat();
        self.senders[dst]
            .send(env)
            .map_err(|_| Error::Cluster(format!("rank {} send to dead rank {dst}", self.rank)))
    }

    fn try_recv(&mut self) -> Option<Envelope<M>> {
        self.beat();
        self.receiver.try_recv().ok()
    }

    /// The blocking receive **is** the deadline receive at the configured
    /// [`recv_guard`] — one timeout path, not two: the guard env override
    /// and every ft/ deadline flow through [`Transport::recv_deadline`].
    fn recv(&mut self) -> Result<Envelope<M>> {
        let guard = recv_guard();
        match self.recv_deadline(guard)? {
            Some(env) => Ok(env),
            None => Err(Error::Cluster(format!(
                "rank {} recv timed out after {guard:?} (protocol deadlock?)",
                self.rank
            ))),
        }
    }

    fn recv_deadline(&mut self, d: Duration) -> Result<Option<Envelope<M>>> {
        self.beat();
        match self.receiver.recv_timeout(d) {
            Ok(env) => Ok(Some(env)),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => {
                Err(Error::Cluster(format!("rank {} peers disconnected", self.rank)))
            }
        }
    }

    fn liveness(&self, rank: usize, stale_after: Duration) -> Liveness {
        match self.shared.state[rank].load(Ordering::Relaxed) {
            LIVE_FAILED | LIVE_DONE => Liveness::Dead,
            _ => {
                let last = self.shared.beat[rank].load(Ordering::Relaxed);
                let now = self.shared.epoch.elapsed().as_micros() as u64;
                if now.saturating_sub(last) > stale_after.as_micros() as u64 {
                    Liveness::Slow
                } else {
                    Liveness::Alive
                }
            }
        }
    }

    fn retire(&mut self, ok: bool) {
        let s = if ok { LIVE_DONE } else { LIVE_FAILED };
        self.shared.state[self.rank].store(s, Ordering::Release);
    }

    fn barrier(&mut self) -> Result<()> {
        self.beat();
        self.shared.barrier.wait();
        Ok(())
    }

    /// Internally: write cell → barrier → rank 0 sums → barrier → read.
    fn reduce_sum(&mut self, value: u64) -> Result<u64> {
        self.beat();
        {
            let mut cells = self.shared.reduce_cells.lock().unwrap();
            cells[self.rank] = value;
        }
        self.shared.barrier.wait();
        if self.rank == 0 {
            let cells = self.shared.reduce_cells.lock().unwrap();
            let sum = cells.iter().sum();
            self.shared.reduce_acc.store(sum, Ordering::SeqCst);
        }
        self.shared.barrier.wait();
        Ok(self.shared.reduce_acc.load(Ordering::SeqCst))
    }
}
