//! The socket fabric: [`Transport`] over length-prefixed binary frames on
//! real TCP streams, so a counting cluster can span OS *processes* (and, in
//! principle, machines) instead of threads in one address space.
//!
//! ## Wire protocol (DESIGN.md §15)
//!
//! Every connection opens with a 24-byte hello — `[MAGIC u32,
//! WIRE_VERSION u32, job_id u64, rank u32, procs u32]`, all little-endian —
//! and then carries a stream of frames: a 20-byte header `[src, dst, tag,
//! control, len]` (five LE `u32`s) followed by `len` payload bytes encoded
//! with the [`Wire`] codec. One ordered TCP stream per (src, dst) pair *is*
//! the non-overtaking guarantee the [`Transport`] contract demands: TCP
//! delivers bytes in order, frames are parsed in order, and the per-peer
//! reader enqueues them in order — nothing can overtake on an edge.
//!
//! Decoding is total: truncated frames, oversized length prefixes,
//! mid-stream disconnects and undecodable payloads all surface as
//! deterministic [`Error::Comm`] (hello-level mismatches as
//! [`Error::Config`]) — never a panic, never a hang (every blocking wait is
//! bounded by [`recv_guard`]).
//!
//! ## Rendezvous
//!
//! Rank 0 hosts: it binds the `--connect` address, accepts `P-1` workers
//! within the join timeout, validates the roster (job id, wire version,
//! duplicate / out-of-range ranks) and broadcasts the peer address table.
//! Each worker binds a mesh listener, presents it in its hello, then dials
//! every lower-ranked worker and accepts from every higher-ranked one —
//! the uniform orientation cannot deadlock because dials complete against
//! the OS listen backlog without a synchronous accept. Rank 0's edges are
//! the rendezvous streams themselves.
//!
//! ## Collectives and results
//!
//! Barriers and reductions ride the same streams as control-tagged frames
//! coordinated by rank 0, keyed by a shared epoch counter (both collectives
//! advance it, so the epoch alone identifies the collective; a fast peer
//! can be at most one epoch ahead, which rank 0 absorbs in a pending map).
//! When the rank program returns, every rank's `(result, metrics)` is
//! gathered at rank 0 and the complete rank-ordered vector is broadcast
//! back, so [`run_tcp_hooked`] returns the *identical* allgather on every
//! rank — the drivers' fold/zip logic works unchanged in every process.
//!
//! ## Byte accounting
//!
//! `CommMetrics::bytes_sent` keeps counting declared [`Payload::size_bytes`]
//! exactly as on the channel fabric; the framing this module adds on top
//! (headers, collective/retire frames) accumulates separately and is
//! stamped into `CommMetrics::wire_overhead_bytes` after the rank program
//! returns. The result/GO frames themselves are sent *after* that stamp
//! and are deliberately excluded — the counter is "overhead during the
//! run", snapshotted at the same instant as every other counter.

use std::collections::BTreeMap;
use std::io::{self, Read, Write};
use std::marker::PhantomData;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use crate::comm::metrics::CommMetrics;
use crate::comm::threads::{recv_guard, try_recv_guard, Cluster, Comm, Progress};
use crate::comm::transport::{
    Envelope, Liveness, Payload, Transport, Wire, WireReader, LIVE_DONE, LIVE_FAILED, LIVE_RUNNING,
};
use crate::error::{Error, Result};

/// First word of every hello: identifies a tricount peer.
pub const MAGIC: u32 = 0x5452_4943;

/// Wire schema version; both ends must agree exactly.
pub const WIRE_VERSION: u32 = 1;

/// Fixed hello size: magic, version, job id, rank, procs.
pub const HELLO_BYTES: usize = 24;

/// Fixed frame header size: `[src, dst, tag, control, len]` as LE u32s.
pub const FRAME_HEADER_BYTES: usize = 20;

/// Upper bound on a single frame payload — a corrupt length prefix fails
/// here instead of driving a multi-gigabyte allocation.
pub const MAX_FRAME_BYTES: usize = 1 << 30;

const MAX_ADDR_BYTES: usize = 4096;
const MAX_REASON_BYTES: usize = 1 << 16;
const MAX_TABLE_BYTES: usize = 1 << 20;

/// Application data plane ([`Envelope`] payloads).
pub const TAG_MSG: u32 = 0;
/// Barrier contribution (worker → rank 0).
pub const TAG_BARRIER: u32 = 1;
/// Barrier release (rank 0 → worker).
pub const TAG_BARRIER_GO: u32 = 2;
/// Reduce contribution (worker → rank 0).
pub const TAG_REDUCE: u32 = 3;
/// Reduce total (rank 0 → worker).
pub const TAG_REDUCE_GO: u32 = 4;
/// Rank retirement; `control` is the success flag.
pub const TAG_RETIRE: u32 = 5;
/// Per-rank result upload (worker → rank 0); `control` = ok flag.
pub const TAG_RESULT: u32 = 6;
/// Allgathered results / failure verdict (rank 0 → worker).
pub const TAG_RESULT_GO: u32 = 7;

/// One decoded frame as it came off the socket.
#[derive(Debug, PartialEq, Eq)]
pub struct RawFrame {
    pub src: u32,
    pub dst: u32,
    pub tag: u32,
    pub control: u32,
    pub payload: Vec<u8>,
}

/// Assemble one frame: 20-byte header + payload.
pub fn encode_frame(src: u32, dst: u32, tag: u32, control: u32, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(FRAME_HEADER_BYTES + payload.len());
    for w in [src, dst, tag, control, payload.len() as u32] {
        out.extend_from_slice(&w.to_le_bytes());
    }
    out.extend_from_slice(payload);
    out
}

/// Read exactly `buf.len()` bytes; EOF or an I/O error mid-read is a
/// deterministic [`Error::Comm`] naming what was being read.
fn read_exact_or<R: Read>(r: &mut R, buf: &mut [u8], what: &str) -> Result<()> {
    let mut got = 0;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => {
                return Err(Error::Comm(format!(
                    "mid-stream disconnect while reading {what}: got {got} of {} bytes",
                    buf.len()
                )))
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(Error::Comm(format!("socket read failed while reading {what}: {e}"))),
        }
    }
    Ok(())
}

/// Read one frame. `Ok(None)` on a clean EOF at a frame boundary (the
/// peer closed after its last complete frame); every partial read is an
/// [`Error::Comm`], and a length prefix beyond [`MAX_FRAME_BYTES`] fails
/// before any allocation.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<RawFrame>> {
    let mut hdr = [0u8; FRAME_HEADER_BYTES];
    let mut got = 0;
    while got < hdr.len() {
        match r.read(&mut hdr[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => {
                return Err(Error::Comm(format!(
                    "mid-stream disconnect: got {got} of {FRAME_HEADER_BYTES} frame-header bytes"
                )))
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(Error::Comm(format!("socket read failed: {e}"))),
        }
    }
    let word = |i: usize| u32::from_le_bytes(hdr[4 * i..4 * i + 4].try_into().unwrap());
    let (src, dst, tag, control, len) = (word(0), word(1), word(2), word(3), word(4));
    if len as usize > MAX_FRAME_BYTES {
        return Err(Error::Comm(format!(
            "frame length {len} exceeds the {MAX_FRAME_BYTES}-byte cap"
        )));
    }
    let mut payload = vec![0u8; len as usize];
    read_exact_or(r, &mut payload, "frame payload")?;
    Ok(Some(RawFrame { src, dst, tag, control, payload }))
}

/// A decoded hello (magic and version already verified).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Hello {
    pub job_id: u64,
    pub rank: u32,
    pub procs: u32,
}

/// Encode the fixed-size connection hello.
pub fn encode_hello(job_id: u64, rank: u32, procs: u32) -> [u8; HELLO_BYTES] {
    let mut b = [0u8; HELLO_BYTES];
    b[0..4].copy_from_slice(&MAGIC.to_le_bytes());
    b[4..8].copy_from_slice(&WIRE_VERSION.to_le_bytes());
    b[8..16].copy_from_slice(&job_id.to_le_bytes());
    b[16..20].copy_from_slice(&rank.to_le_bytes());
    b[20..24].copy_from_slice(&procs.to_le_bytes());
    b
}

/// Read and validate a hello: a non-tricount peer ([`MAGIC`]) or a build
/// from a different wire schema ([`WIRE_VERSION`]) is an [`Error::Config`]
/// — a deployment mistake, not a transient wire fault.
pub fn read_hello<R: Read>(r: &mut R) -> Result<Hello> {
    let mut b = [0u8; HELLO_BYTES];
    read_exact_or(r, &mut b, "hello")?;
    let magic = u32::from_le_bytes(b[0..4].try_into().unwrap());
    if magic != MAGIC {
        return Err(Error::Config(format!(
            "bad rendezvous magic {magic:#010x} (expected {MAGIC:#010x}) — not a tricount peer"
        )));
    }
    let version = u32::from_le_bytes(b[4..8].try_into().unwrap());
    if version != WIRE_VERSION {
        return Err(Error::Config(format!(
            "wire version mismatch: peer speaks v{version}, this build speaks v{WIRE_VERSION}"
        )));
    }
    Ok(Hello {
        job_id: u64::from_le_bytes(b[8..16].try_into().unwrap()),
        rank: u32::from_le_bytes(b[16..20].try_into().unwrap()),
        procs: u32::from_le_bytes(b[20..24].try_into().unwrap()),
    })
}

/// Append a `u64` count followed by each element's encoding.
pub fn write_seq<T: Wire>(items: &[T], out: &mut Vec<u8>) {
    (items.len() as u64).write_to(out);
    for it in items {
        it.write_to(out);
    }
}

/// Inverse of [`write_seq`]; the count is validated as a length prefix so
/// a corrupt value fails before allocation.
pub fn read_seq<T: Wire>(r: &mut WireReader<'_>) -> Result<Vec<T>> {
    let n = r.len_prefix(1)?;
    let mut v = Vec::with_capacity(n);
    for _ in 0..n {
        v.push(T::read_from(r)?);
    }
    Ok(v)
}

/// Write a `u64`-length-prefixed byte blob (rendezvous metadata, sent raw
/// before the frame readers start).
fn write_blob<W: Write>(w: &mut W, bytes: &[u8]) -> Result<()> {
    w.write_all(&(bytes.len() as u64).to_le_bytes())
        .and_then(|_| w.write_all(bytes))
        .map_err(|e| Error::Comm(format!("rendezvous write failed: {e}")))
}

/// Read a blob with an explicit size cap ([`Error::Config`] above it).
fn read_blob<R: Read>(r: &mut R, cap: usize, what: &str) -> Result<Vec<u8>> {
    let mut hdr = [0u8; 8];
    read_exact_or(r, &mut hdr, what)?;
    let n = u64::from_le_bytes(hdr);
    if n > cap as u64 {
        return Err(Error::Config(format!("{what} length {n} exceeds the {cap}-byte cap")));
    }
    let mut buf = vec![0u8; n as usize];
    read_exact_or(r, &mut buf, what)?;
    Ok(buf)
}

/// Configuration of one rank's endpoint into a TCP cluster, carried by
/// `Fabric::Tcp` and built by the CLI (`tricount worker` / `launch`).
#[derive(Clone, Debug)]
pub struct TcpFabric {
    /// Rendezvous address: rank 0 binds it, workers dial it.
    pub connect: String,
    /// This process's rank in `0..procs`.
    pub rank: usize,
    /// Cluster size `P`.
    pub procs: usize,
    /// Launch-unique id; a worker from a different launch is rejected at
    /// rendezvous instead of silently joining the wrong cluster.
    pub job_id: u64,
    /// Rendezvous join timeout in milliseconds; `0` means "use the
    /// [`recv_guard`]", which is how `TRICOUNT_RECV_GUARD_SECS` bounds a
    /// worker whose peers never connect.
    pub join_timeout_ms: u64,
}

impl TcpFabric {
    /// Effective join timeout (see [`TcpFabric::join_timeout_ms`]).
    pub fn join_timeout(&self) -> Duration {
        if self.join_timeout_ms == 0 {
            recv_guard()
        } else {
            Duration::from_millis(self.join_timeout_ms)
        }
    }
}

/// Per-peer liveness board: run state + last-heard stamp, updated by the
/// reader threads on every frame and read by [`Transport::liveness`] —
/// the same semantics the channel fabric's shared board provides, so the
/// `ft/` supervisor's slow-vs-dead classification carries over.
struct Board {
    state: Vec<AtomicU8>,
    beat: Vec<AtomicU64>,
    epoch: Instant,
}

impl Board {
    fn new(p: usize) -> Arc<Board> {
        Arc::new(Board {
            state: (0..p).map(|_| AtomicU8::new(LIVE_RUNNING)).collect(),
            beat: (0..p).map(|_| AtomicU64::new(0)).collect(),
            epoch: Instant::now(),
        })
    }

    #[inline]
    fn beat_now(&self, rank: usize) {
        self.beat[rank].store(self.epoch.elapsed().as_micros() as u64, Ordering::Relaxed);
    }

    fn set_state(&self, rank: usize, s: u8) {
        self.state[rank].store(s, Ordering::Release);
    }

    fn classify(&self, rank: usize, stale_after: Duration) -> Liveness {
        match self.state[rank].load(Ordering::Acquire) {
            LIVE_DONE | LIVE_FAILED => Liveness::Dead,
            _ => {
                let last = self.beat[rank].load(Ordering::Relaxed);
                let now = self.epoch.elapsed().as_micros() as u64;
                if now.saturating_sub(last) > stale_after.as_micros() as u64 {
                    Liveness::Slow
                } else {
                    Liveness::Alive
                }
            }
        }
    }
}

/// Data-plane delivery from a reader thread to the rank thread. Payload
/// stays as bytes: `M` is deserialized *in the rank thread*, so a corrupt
/// payload surfaces as that rank's deterministic receive error, never as
/// a reader-thread panic.
enum MailItem {
    Env { src: usize, control: bool, bytes: Vec<u8> },
    Fault(String),
}

/// Collective-plane delivery (barrier/reduce contributions and GOs).
enum CollItem {
    Frame { src: usize, tag: u32, epoch: u64, value: u64 },
    Fault(String),
}

/// Result-plane delivery (the end-of-run allgather).
enum ResultItem {
    Frame { src: usize, tag: u32, control: u32, bytes: Vec<u8> },
    Fault(String),
}

/// Serialize one frame onto the (mutex-guarded) stream to `dst`.
fn write_frame(
    writers: &[Option<Arc<Mutex<TcpStream>>>],
    my_rank: usize,
    dst: usize,
    frame: &[u8],
) -> Result<()> {
    let w = writers
        .get(dst)
        .and_then(|w| w.as_ref())
        .ok_or_else(|| Error::Cluster(format!("rank {my_rank}: no stream to rank {dst}")))?;
    let mut s = match w.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    };
    s.write_all(frame)
        .map_err(|e| Error::Cluster(format!("rank {my_rank} send to rank {dst} failed: {e}")))
}

/// One byte-level reader per peer: parses frames off the stream and
/// demuxes by tag into the mail/collective/result queues. `M`-agnostic by
/// design — any wire-level failure becomes a `Fault` pushed to all three
/// queues plus a `FAILED` mark on the board, and the thread exits.
#[allow(clippy::too_many_arguments)]
fn spawn_reader(
    me: usize,
    peer: usize,
    stream: TcpStream,
    board: Arc<Board>,
    closing: Arc<AtomicBool>,
    mail_tx: Sender<MailItem>,
    coll_tx: Sender<CollItem>,
    result_tx: Sender<ResultItem>,
) -> JoinHandle<()> {
    thread::spawn(move || {
        let mut r = io::BufReader::new(stream);
        let fault = |m: String| {
            let _ = mail_tx.send(MailItem::Fault(m.clone()));
            let _ = coll_tx.send(CollItem::Fault(m.clone()));
            let _ = result_tx.send(ResultItem::Fault(m));
        };
        loop {
            match read_frame(&mut r) {
                Ok(None) => {
                    // Clean EOF. If the peer is still marked running and we
                    // are not tearing down ourselves, it died mid-run.
                    if !closing.load(Ordering::Acquire)
                        && board.state[peer].load(Ordering::Acquire) == LIVE_RUNNING
                    {
                        board.set_state(peer, LIVE_FAILED);
                        fault(format!("rank {peer} disconnected mid-run"));
                    }
                    return;
                }
                Ok(Some(f)) => {
                    board.beat_now(peer);
                    if f.dst as usize != me {
                        board.set_state(peer, LIVE_FAILED);
                        fault(format!(
                            "misrouted frame from rank {}: dst {} arrived at rank {me}",
                            f.src, f.dst
                        ));
                        return;
                    }
                    match f.tag {
                        TAG_MSG => {
                            let _ = mail_tx.send(MailItem::Env {
                                src: f.src as usize,
                                control: f.control != 0,
                                bytes: f.payload,
                            });
                        }
                        TAG_BARRIER | TAG_BARRIER_GO | TAG_REDUCE | TAG_REDUCE_GO => {
                            match <(u64, u64)>::from_bytes(&f.payload) {
                                Ok((epoch, value)) => {
                                    let _ = coll_tx.send(CollItem::Frame {
                                        src: f.src as usize,
                                        tag: f.tag,
                                        epoch,
                                        value,
                                    });
                                }
                                Err(e) => {
                                    board.set_state(peer, LIVE_FAILED);
                                    fault(format!("rank {peer}: undecodable collective frame: {e}"));
                                    return;
                                }
                            }
                        }
                        TAG_RETIRE => {
                            board.set_state(
                                peer,
                                if f.control != 0 { LIVE_DONE } else { LIVE_FAILED },
                            );
                        }
                        TAG_RESULT | TAG_RESULT_GO => {
                            let _ = result_tx.send(ResultItem::Frame {
                                src: f.src as usize,
                                tag: f.tag,
                                control: f.control,
                                bytes: f.payload,
                            });
                        }
                        other => {
                            board.set_state(peer, LIVE_FAILED);
                            fault(format!("unknown frame tag {other} from rank {}", f.src));
                            return;
                        }
                    }
                }
                Err(e) => {
                    if closing.load(Ordering::Acquire) {
                        return;
                    }
                    board.set_state(peer, LIVE_FAILED);
                    fault(e.to_string());
                    return;
                }
            }
        }
    })
}

/// Read a hello from an accepted rendezvous connection and validate it
/// against this launch; returns the worker's rank and mesh address.
fn admit(cfg: &TcpFabric, s: &mut TcpStream) -> Result<(usize, String)> {
    let hello = read_hello(s)?;
    if hello.job_id != cfg.job_id {
        return Err(Error::Config(format!(
            "rendezvous job-id mismatch: worker presented {:#x}, this launch is {:#x}",
            hello.job_id, cfg.job_id
        )));
    }
    if hello.procs as usize != cfg.procs {
        return Err(Error::Config(format!(
            "rendezvous procs mismatch: worker built for P={}, this launch is P={}",
            hello.procs, cfg.procs
        )));
    }
    let r = hello.rank as usize;
    if r == 0 || r >= cfg.procs {
        return Err(Error::Config(format!(
            "rendezvous rank {r} out of range 1..{}",
            cfg.procs
        )));
    }
    let addr_bytes = read_blob(s, MAX_ADDR_BYTES, "mesh address")?;
    let addr = String::from_bytes(&addr_bytes)?;
    Ok((r, addr))
}

/// Rank 0's side of the rendezvous: accept, validate, broadcast the peer
/// table (or the rejection reason). Returns the per-peer streams, `None`
/// at index 0.
fn host_rendezvous(cfg: &TcpFabric) -> Result<Vec<Option<TcpStream>>> {
    let listener = TcpListener::bind(&cfg.connect).map_err(|e| {
        Error::Config(format!("cannot bind rendezvous address {}: {e}", cfg.connect))
    })?;
    listener
        .set_nonblocking(true)
        .map_err(|e| Error::Comm(format!("cannot make rendezvous listener non-blocking: {e}")))?;
    let deadline = Instant::now() + cfg.join_timeout();
    let mut streams: Vec<Option<TcpStream>> = (0..cfg.procs).map(|_| None).collect();
    let mut addrs: Vec<String> = vec![String::new(); cfg.procs];
    let mut joined = 1usize; // rank 0 is the host

    let outcome: Result<()> = loop {
        if joined == cfg.procs {
            break Ok(());
        }
        if Instant::now() >= deadline {
            let missing: Vec<String> = (1..cfg.procs)
                .filter(|r| streams[*r].is_none())
                .map(|r| r.to_string())
                .collect();
            break Err(Error::Config(format!(
                "rendezvous join timeout after {:?}: missing rank(s) {}",
                cfg.join_timeout(),
                missing.join(", ")
            )));
        }
        match listener.accept() {
            Ok((mut s, _peer)) => {
                if let Err(e) = s.set_nonblocking(false) {
                    break Err(Error::Comm(format!("rendezvous socket setup failed: {e}")));
                }
                s.set_nodelay(true).ok();
                match admit(cfg, &mut s) {
                    Ok((r, addr)) => {
                        if streams[r].is_some() {
                            break Err(Error::Config(format!("duplicate rank {r} at rendezvous")));
                        }
                        streams[r] = Some(s);
                        addrs[r] = addr;
                        joined += 1;
                    }
                    Err(e) => break Err(e),
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(5));
            }
            Err(e) => break Err(Error::Comm(format!("rendezvous accept failed: {e}"))),
        }
    };
    match outcome {
        Ok(()) => {
            let mut table = Vec::new();
            write_seq(&addrs, &mut table);
            for r in 1..cfg.procs {
                let s = streams[r].as_mut().expect("all ranks joined");
                s.write_all(&[0u8]).map_err(|e| {
                    Error::Comm(format!("rendezvous table send to rank {r} failed: {e}"))
                })?;
                write_blob(s, &table)?;
            }
            Ok(streams)
        }
        Err(e) => {
            // Tell every already-joined worker why before failing rank 0,
            // so they exit with the reason instead of a bare disconnect.
            let mut reason = Vec::new();
            e.to_string().write_to(&mut reason);
            for s in streams.iter_mut().flatten() {
                let _ = s.write_all(&[1u8]);
                let _ = write_blob(s, &reason);
            }
            Err(e)
        }
    }
}

/// A worker's side of the rendezvous plus the mesh dial-up. Returns the
/// per-peer streams, `None` at this rank's own index.
fn worker_rendezvous(cfg: &TcpFabric) -> Result<Vec<Option<TcpStream>>> {
    let deadline = Instant::now() + cfg.join_timeout();
    // Dial rank 0 with bounded retry — the host may not have bound yet.
    let mut s0 = loop {
        match TcpStream::connect(&cfg.connect) {
            Ok(s) => break s,
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(Error::Config(format!(
                        "rank {}: could not reach rendezvous at {} within {:?}: {e}",
                        cfg.rank,
                        cfg.connect,
                        cfg.join_timeout()
                    )));
                }
                thread::sleep(Duration::from_millis(25));
            }
        }
    };
    s0.set_nodelay(true).ok();
    // Mesh listener, advertised to higher ranks through rank 0's table.
    // Bound on the same interface the rendezvous connection uses, so the
    // advertised address is reachable in multi-host deployments too.
    let local_ip = s0
        .local_addr()
        .map_err(|e| Error::Comm(format!("local_addr failed: {e}")))?
        .ip();
    let mesh = TcpListener::bind(SocketAddr::new(local_ip, 0))
        .map_err(|e| Error::Comm(format!("rank {}: cannot bind mesh listener: {e}", cfg.rank)))?;
    let mesh_addr = mesh
        .local_addr()
        .map_err(|e| Error::Comm(format!("mesh local_addr failed: {e}")))?
        .to_string();

    s0.write_all(&encode_hello(cfg.job_id, cfg.rank as u32, cfg.procs as u32))
        .map_err(|e| Error::Comm(format!("rendezvous hello send failed: {e}")))?;
    let mut addr_enc = Vec::new();
    mesh_addr.write_to(&mut addr_enc);
    write_blob(&mut s0, &addr_enc)?;

    let mut status = [0u8; 1];
    read_exact_or(&mut s0, &mut status, "rendezvous status")?;
    if status[0] == 1 {
        let reason = read_blob(&mut s0, MAX_REASON_BYTES, "rendezvous rejection")?;
        let msg = String::from_bytes(&reason)?;
        return Err(Error::Config(format!(
            "rank {}: rendezvous rejected this worker: {msg}",
            cfg.rank
        )));
    }
    if status[0] != 0 {
        return Err(Error::Comm(format!("invalid rendezvous status byte {}", status[0])));
    }
    let table_bytes = read_blob(&mut s0, MAX_TABLE_BYTES, "peer address table")?;
    let mut rd = WireReader::new(&table_bytes);
    let table = read_seq::<String>(&mut rd)?;
    rd.finish()?;
    if table.len() != cfg.procs {
        return Err(Error::Comm(format!(
            "peer table has {} entries, expected {}",
            table.len(),
            cfg.procs
        )));
    }

    let mut streams: Vec<Option<TcpStream>> = (0..cfg.procs).map(|_| None).collect();
    streams[0] = Some(s0);
    // Dial every lower-ranked worker; accept from every higher one. The
    // uniform orientation cannot deadlock: dials complete against the OS
    // listen backlog without a synchronous accept on the other side.
    for i in 1..cfg.rank {
        let mut s = loop {
            match TcpStream::connect(table[i].as_str()) {
                Ok(s) => break s,
                Err(e) => {
                    if Instant::now() >= deadline {
                        return Err(Error::Comm(format!(
                            "rank {}: could not reach rank {i} at {} within the join timeout: {e}",
                            cfg.rank, table[i]
                        )));
                    }
                    thread::sleep(Duration::from_millis(10));
                }
            }
        };
        s.set_nodelay(true).ok();
        s.write_all(&encode_hello(cfg.job_id, cfg.rank as u32, cfg.procs as u32))
            .map_err(|e| Error::Comm(format!("mesh hello to rank {i} failed: {e}")))?;
        streams[i] = Some(s);
    }
    mesh.set_nonblocking(true)
        .map_err(|e| Error::Comm(format!("mesh listener setup failed: {e}")))?;
    let expected = cfg.procs - cfg.rank - 1;
    let mut accepted = 0;
    while accepted < expected {
        if Instant::now() >= deadline {
            return Err(Error::Comm(format!(
                "rank {}: mesh join timeout: {accepted} of {expected} higher-ranked peers connected",
                cfg.rank
            )));
        }
        match mesh.accept() {
            Ok((mut s, _)) => {
                s.set_nonblocking(false)
                    .map_err(|e| Error::Comm(format!("mesh socket setup failed: {e}")))?;
                s.set_nodelay(true).ok();
                let hello = read_hello(&mut s)?;
                let j = hello.rank as usize;
                if hello.job_id != cfg.job_id || hello.procs as usize != cfg.procs {
                    return Err(Error::Config(format!(
                        "rank {}: mesh hello mismatch from rank {j}",
                        cfg.rank
                    )));
                }
                if j <= cfg.rank || j >= cfg.procs || streams[j].is_some() {
                    return Err(Error::Comm(format!(
                        "rank {}: unexpected mesh hello from rank {j}",
                        cfg.rank
                    )));
                }
                streams[j] = Some(s);
                accepted += 1;
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(5))
            }
            Err(e) => return Err(Error::Comm(format!("mesh accept failed: {e}"))),
        }
    }
    Ok(streams)
}

/// The socket-side resources that must outlive the rank program: writers,
/// raw stream handles (for shutdown), the reader threads and the result
/// queue. Owned by [`run_tcp_hooked`], *not* by the transport — the
/// `Comm` is consumed by `Cluster::launch`, and the end-of-run result
/// exchange still needs the sockets after it returns.
pub(crate) struct TcpSession {
    rank: usize,
    writers: Vec<Option<Arc<Mutex<TcpStream>>>>,
    raw: Vec<Option<TcpStream>>,
    closing: Arc<AtomicBool>,
    overhead: Arc<AtomicU64>,
    result_rx: Receiver<ResultItem>,
    readers: Vec<JoinHandle<()>>,
}

impl TcpSession {
    fn write_frame_to(&self, dst: usize, frame: &[u8]) -> Result<()> {
        write_frame(&self.writers, self.rank, dst, frame)
    }

    /// Framing bytes accumulated so far (see the module docs on stamping).
    fn overhead_bytes(&self) -> u64 {
        self.overhead.load(Ordering::Relaxed)
    }

    /// Tear down: mark closing (so our readers treat the wakeup as clean),
    /// shut both directions of every socket — which unblocks this
    /// process's own blocked `read`s with EOF — and join the readers.
    pub(crate) fn shutdown(&mut self) {
        self.closing.store(true, Ordering::SeqCst);
        for s in self.raw.iter().flatten() {
            let _ = s.shutdown(Shutdown::Both);
        }
        for h in self.readers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for TcpSession {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// One rank's [`Transport`] endpoint over the TCP mesh.
pub struct TcpTransport<M: Payload> {
    rank: usize,
    procs: usize,
    writers: Vec<Option<Arc<Mutex<TcpStream>>>>,
    board: Arc<Board>,
    overhead: Arc<AtomicU64>,
    /// Self-sends short-circuit into our own mailbox (no wire, no
    /// overhead) — mirroring the channel fabric, where a self-send goes
    /// through the same queue as remote deliveries.
    mail_tx: Sender<MailItem>,
    mail_rx: Receiver<MailItem>,
    coll_rx: Receiver<CollItem>,
    /// Shared collective epoch: both [`Transport::barrier`] and
    /// [`Transport::reduce_sum`] advance it, so identical collective
    /// sequences on all ranks mean the epoch alone names the collective.
    epoch: u64,
    /// Rank 0 only: early contributions to a *future* epoch (a fast peer
    /// is at most one ahead — it cannot pass epoch `e+1` without our GO
    /// for `e`), keyed by epoch as `(count, partial_sum)`.
    pending: BTreeMap<u64, (usize, u64)>,
    /// A wire fault observed by [`Transport::try_recv`] (which has no
    /// error channel): stashed here and surfaced by the next fallible
    /// receive or collective.
    pending_fault: Option<String>,
    _msg: PhantomData<M>,
}

impl<M: Payload> TcpTransport<M> {
    fn check_fault(&self) -> Result<()> {
        match &self.pending_fault {
            Some(m) => Err(Error::Comm(m.clone())),
            None => Ok(()),
        }
    }

    /// The rank-0-coordinated collective shared by barrier and reduce:
    /// workers send `(epoch, value)` to rank 0; rank 0 sums `P-1`
    /// contributions for the current epoch (stashing early next-epoch
    /// ones) and broadcasts `(epoch, total)` as the GO.
    fn collective(&mut self, contrib_tag: u32, go_tag: u32, value: u64) -> Result<u64> {
        self.board.beat_now(self.rank);
        self.check_fault()?;
        let epoch = self.epoch;
        self.epoch += 1;
        if self.procs == 1 {
            return Ok(value);
        }
        let deadline = Instant::now() + recv_guard();
        if self.rank == 0 {
            let (mut have, mut sum) = self.pending.remove(&epoch).unwrap_or((0, 0));
            while have < self.procs - 1 {
                let left = deadline.saturating_duration_since(Instant::now());
                if left.is_zero() {
                    return Err(Error::Cluster(format!(
                        "rank 0 collective epoch {epoch} timed out after {:?} ({have}/{} contributions)",
                        recv_guard(),
                        self.procs - 1
                    )));
                }
                match self.coll_rx.recv_timeout(left) {
                    Ok(CollItem::Frame { src, tag, epoch: e, value: v }) => {
                        if e == epoch {
                            if tag != contrib_tag {
                                return Err(Error::Comm(format!(
                                    "collective tag mismatch at epoch {epoch}: rank {src} sent tag {tag}, expected {contrib_tag}"
                                )));
                            }
                            have += 1;
                            sum += v;
                        } else if e > epoch {
                            let slot = self.pending.entry(e).or_insert((0, 0));
                            slot.0 += 1;
                            slot.1 += v;
                        } else {
                            return Err(Error::Comm(format!(
                                "stale collective epoch {e} from rank {src} (rank 0 is at epoch {epoch})"
                            )));
                        }
                    }
                    Ok(CollItem::Fault(m)) => return Err(Error::Comm(m)),
                    Err(RecvTimeoutError::Timeout) => continue,
                    Err(RecvTimeoutError::Disconnected) => {
                        return Err(Error::Cluster(format!(
                            "rank {} peers disconnected",
                            self.rank
                        )))
                    }
                }
            }
            let total = sum + value;
            let mut buf = Vec::new();
            epoch.write_to(&mut buf);
            total.write_to(&mut buf);
            for dst in 1..self.procs {
                let frame = encode_frame(0, dst as u32, go_tag, 0, &buf);
                self.overhead.fetch_add(frame.len() as u64, Ordering::Relaxed);
                write_frame(&self.writers, self.rank, dst, &frame)?;
            }
            Ok(total)
        } else {
            let mut buf = Vec::new();
            epoch.write_to(&mut buf);
            value.write_to(&mut buf);
            let frame = encode_frame(self.rank as u32, 0, contrib_tag, 0, &buf);
            self.overhead.fetch_add(frame.len() as u64, Ordering::Relaxed);
            write_frame(&self.writers, self.rank, 0, &frame)?;
            loop {
                let left = deadline.saturating_duration_since(Instant::now());
                if left.is_zero() {
                    return Err(Error::Cluster(format!(
                        "rank {} collective epoch {epoch} timed out waiting for rank 0",
                        self.rank
                    )));
                }
                match self.coll_rx.recv_timeout(left) {
                    Ok(CollItem::Frame { src, tag, epoch: e, value: total }) => {
                        // GOs arrive on rank 0's FIFO edge, so the next one
                        // must be ours — anything else is protocol skew.
                        if src != 0 || tag != go_tag || e != epoch {
                            return Err(Error::Comm(format!(
                                "collective epoch mismatch: rank {} at epoch {epoch} (tag {go_tag}) got tag {tag} epoch {e} from rank {src}",
                                self.rank
                            )));
                        }
                        return Ok(total);
                    }
                    Ok(CollItem::Fault(m)) => return Err(Error::Comm(m)),
                    Err(RecvTimeoutError::Timeout) => continue,
                    Err(RecvTimeoutError::Disconnected) => {
                        return Err(Error::Cluster(format!(
                            "rank {} peers disconnected",
                            self.rank
                        )))
                    }
                }
            }
        }
    }
}

impl<M: Payload> Transport<M> for TcpTransport<M> {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.procs
    }

    fn send(&mut self, dst: usize, env: Envelope<M>) -> Result<()> {
        self.board.beat_now(self.rank);
        if dst == self.rank {
            return self
                .mail_tx
                .send(MailItem::Env {
                    src: env.src,
                    control: env.control,
                    bytes: env.msg.to_bytes(),
                })
                .map_err(|_| {
                    Error::Cluster(format!("rank {} self-send failed (mailbox closed)", self.rank))
                });
        }
        let payload = env.msg.to_bytes();
        let frame =
            encode_frame(self.rank as u32, dst as u32, TAG_MSG, env.control as u32, &payload);
        // Framing overhead = actual frame bytes beyond the declared
        // payload size (`Payload::size_bytes` stays the byte-accounting
        // truth for `bytes_sent` on every fabric).
        self.overhead
            .fetch_add((frame.len() as u64).saturating_sub(env.msg.size_bytes()), Ordering::Relaxed);
        write_frame(&self.writers, self.rank, dst, &frame)
    }

    fn try_recv(&mut self) -> Option<Envelope<M>> {
        self.board.beat_now(self.rank);
        if self.pending_fault.is_some() {
            return None;
        }
        match self.mail_rx.try_recv() {
            Ok(MailItem::Env { src, control, bytes }) => match M::from_bytes(&bytes) {
                Ok(msg) => Some(Envelope { src, control, msg }),
                Err(e) => {
                    // No error channel here — stash for the next fallible op.
                    self.pending_fault = Some(format!("rank {}: {e}", self.rank));
                    None
                }
            },
            Ok(MailItem::Fault(m)) => {
                self.pending_fault = Some(m);
                None
            }
            Err(_) => None,
        }
    }

    fn recv(&mut self) -> Result<Envelope<M>> {
        let guard = recv_guard();
        match self.recv_deadline(guard)? {
            Some(env) => Ok(env),
            None => Err(Error::Cluster(format!(
                "rank {} recv timed out after {guard:?} (protocol deadlock?)",
                self.rank
            ))),
        }
    }

    fn recv_deadline(&mut self, d: Duration) -> Result<Option<Envelope<M>>> {
        self.board.beat_now(self.rank);
        self.check_fault()?;
        match self.mail_rx.recv_timeout(d) {
            Ok(MailItem::Env { src, control, bytes }) => {
                let msg = M::from_bytes(&bytes)
                    .map_err(|e| Error::Comm(format!("rank {}: {e}", self.rank)))?;
                Ok(Some(Envelope { src, control, msg }))
            }
            Ok(MailItem::Fault(m)) => Err(Error::Comm(m)),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => {
                Err(Error::Cluster(format!("rank {} peers disconnected", self.rank)))
            }
        }
    }

    fn liveness(&self, rank: usize, stale_after: Duration) -> Liveness {
        self.board.classify(rank, stale_after)
    }

    fn retire(&mut self, ok: bool) {
        let ctrl = ok as u32;
        for dst in 0..self.procs {
            if dst == self.rank {
                continue;
            }
            let frame = encode_frame(self.rank as u32, dst as u32, TAG_RETIRE, ctrl, &[]);
            self.overhead.fetch_add(frame.len() as u64, Ordering::Relaxed);
            // Best-effort: a peer that already tore down must not turn our
            // clean exit into an error.
            let _ = write_frame(&self.writers, self.rank, dst, &frame);
        }
        self.board.set_state(self.rank, if ok { LIVE_DONE } else { LIVE_FAILED });
    }

    fn barrier(&mut self) -> Result<()> {
        self.collective(TAG_BARRIER, TAG_BARRIER_GO, 0).map(|_| ())
    }

    fn reduce_sum(&mut self, value: u64) -> Result<u64> {
        self.collective(TAG_REDUCE, TAG_REDUCE_GO, value)
    }
}

/// Wire up one rank's endpoint: rendezvous, mesh dial-up, reader threads.
pub(crate) fn establish<M: Payload>(cfg: &TcpFabric) -> Result<(TcpTransport<M>, TcpSession)> {
    if cfg.procs == 0 {
        return Err(Error::Config("tcp fabric needs --procs >= 1".into()));
    }
    if cfg.rank >= cfg.procs {
        return Err(Error::Config(format!(
            "--rank {} out of range for --procs {}",
            cfg.rank, cfg.procs
        )));
    }
    let peer_streams: Vec<Option<TcpStream>> = if cfg.procs == 1 {
        vec![None]
    } else if cfg.rank == 0 {
        host_rendezvous(cfg)?
    } else {
        worker_rendezvous(cfg)?
    };

    let (mail_tx, mail_rx) = mpsc::channel();
    let (coll_tx, coll_rx) = mpsc::channel();
    let (result_tx, result_rx) = mpsc::channel();
    let board = Board::new(cfg.procs);
    let closing = Arc::new(AtomicBool::new(false));
    let overhead = Arc::new(AtomicU64::new(0));

    let mut writers: Vec<Option<Arc<Mutex<TcpStream>>>> = (0..cfg.procs).map(|_| None).collect();
    let mut raw: Vec<Option<TcpStream>> = (0..cfg.procs).map(|_| None).collect();
    let mut readers = Vec::new();
    for (peer, s) in peer_streams.into_iter().enumerate() {
        let s = match s {
            Some(s) => s,
            None => continue,
        };
        let clone_err = |e: io::Error| Error::Comm(format!("stream clone failed: {e}"));
        let reader_half = s.try_clone().map_err(clone_err)?;
        raw[peer] = Some(s.try_clone().map_err(clone_err)?);
        writers[peer] = Some(Arc::new(Mutex::new(s)));
        readers.push(spawn_reader(
            cfg.rank,
            peer,
            reader_half,
            board.clone(),
            closing.clone(),
            mail_tx.clone(),
            coll_tx.clone(),
            result_tx.clone(),
        ));
    }

    let transport = TcpTransport {
        rank: cfg.rank,
        procs: cfg.procs,
        writers: writers.clone(),
        board,
        overhead: overhead.clone(),
        mail_tx,
        mail_rx,
        coll_rx,
        epoch: 0,
        pending: BTreeMap::new(),
        pending_fault: None,
        _msg: PhantomData,
    };
    let session = TcpSession {
        rank: cfg.rank,
        writers,
        raw,
        closing,
        overhead,
        result_rx,
        readers,
    };
    Ok((transport, session))
}

/// `(ops, msg)` for broadcasting a failure verdict.
fn failure_parts(e: &Error) -> (u64, String) {
    match e {
        Error::RankFailure { ops, msg, .. } => (*ops, msg.clone()),
        other => (0, other.to_string()),
    }
}

/// End-of-run allgather (see the module docs): workers upload their
/// `(result, metrics)` to rank 0; rank 0 assembles the rank-ordered
/// vector (or attributes the earliest failure, mirroring the launcher's
/// min-(ops, rank) rule) and broadcasts the verdict.
fn exchange_results<R: Wire>(
    session: &TcpSession,
    cfg: &TcpFabric,
    local: Result<(R, CommMetrics)>,
) -> Result<Vec<(R, CommMetrics)>> {
    if cfg.rank != 0 {
        let frame = match &local {
            Ok((r, m)) => {
                let mut buf = Vec::new();
                r.write_to(&mut buf);
                m.write_to(&mut buf);
                encode_frame(cfg.rank as u32, 0, TAG_RESULT, 1, &buf)
            }
            Err(e) => {
                let (ops, msg) = failure_parts(e);
                let mut buf = Vec::new();
                ops.write_to(&mut buf);
                msg.write_to(&mut buf);
                encode_frame(cfg.rank as u32, 0, TAG_RESULT, 0, &buf)
            }
        };
        session.write_frame_to(0, &frame)?;
        let deadline = Instant::now() + recv_guard();
        loop {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return Err(Error::Cluster(format!(
                    "rank {} result exchange timed out after {:?}",
                    cfg.rank,
                    recv_guard()
                )));
            }
            match session.result_rx.recv_timeout(left) {
                Ok(ResultItem::Frame { src, tag, control, bytes }) => {
                    if src != 0 || tag != TAG_RESULT_GO {
                        return Err(Error::Comm(format!(
                            "unexpected result-plane frame (tag {tag}) from rank {src}"
                        )));
                    }
                    if control == 1 {
                        let mut rd = WireReader::new(&bytes);
                        let all = read_seq::<(R, CommMetrics)>(&mut rd)?;
                        rd.finish()?;
                        if all.len() != cfg.procs {
                            return Err(Error::Comm(format!(
                                "result allgather has {} entries, expected {}",
                                all.len(),
                                cfg.procs
                            )));
                        }
                        return Ok(all);
                    }
                    let mut rd = WireReader::new(&bytes);
                    let rank = rd.u64()? as usize;
                    let ops = rd.u64()?;
                    let msg = String::read_from(&mut rd)?;
                    rd.finish()?;
                    return Err(Error::RankFailure { rank, ops, msg });
                }
                Ok(ResultItem::Fault(m)) => return Err(Error::Comm(m)),
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(Error::Cluster(format!(
                        "rank {} peers disconnected during result exchange",
                        cfg.rank
                    )))
                }
            }
        }
    }

    // Rank 0: gather P-1 uploads, then broadcast the verdict.
    let mut slots: Vec<Option<Result<(R, CommMetrics)>>> = (0..cfg.procs).map(|_| None).collect();
    slots[0] = Some(local);
    let gathered: Result<()> = (|| {
        let deadline = Instant::now() + recv_guard();
        let mut have = 1usize;
        while have < cfg.procs {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                let missing: Vec<String> = (1..cfg.procs)
                    .filter(|r| slots[*r].is_none())
                    .map(|r| r.to_string())
                    .collect();
                return Err(Error::Cluster(format!(
                    "rank 0 timed out gathering results; missing rank(s) {}",
                    missing.join(", ")
                )));
            }
            match session.result_rx.recv_timeout(left) {
                Ok(ResultItem::Frame { src, tag, control, bytes }) => {
                    if tag != TAG_RESULT {
                        return Err(Error::Comm(format!(
                            "unexpected result-plane tag {tag} from rank {src}"
                        )));
                    }
                    if src == 0 || src >= cfg.procs || slots[src].is_some() {
                        return Err(Error::Comm(format!(
                            "duplicate or out-of-range result from rank {src}"
                        )));
                    }
                    let parsed: Result<(R, CommMetrics)> = if control == 1 {
                        let mut rd = WireReader::new(&bytes);
                        let r = R::read_from(&mut rd)?;
                        let m = CommMetrics::read_from(&mut rd)?;
                        rd.finish()?;
                        Ok((r, m))
                    } else {
                        let mut rd = WireReader::new(&bytes);
                        let ops = rd.u64()?;
                        let msg = String::read_from(&mut rd)?;
                        rd.finish()?;
                        Err(Error::RankFailure { rank: src, ops, msg })
                    };
                    slots[src] = Some(parsed);
                    have += 1;
                }
                Ok(ResultItem::Fault(m)) => return Err(Error::Comm(m)),
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(Error::Cluster(
                        "rank 0 peers disconnected during result gather".into(),
                    ))
                }
            }
        }
        Ok(())
    })();

    let broadcast_error = |rank: usize, ops: u64, msg: &str| {
        let mut buf = Vec::new();
        (rank as u64).write_to(&mut buf);
        ops.write_to(&mut buf);
        msg.to_string().write_to(&mut buf);
        for dst in 1..cfg.procs {
            let frame = encode_frame(0, dst as u32, TAG_RESULT_GO, 0, &buf);
            let _ = session.write_frame_to(dst, &frame);
        }
    };

    if let Err(e) = gathered {
        let (ops, msg) = failure_parts(&e);
        broadcast_error(0, ops, &msg);
        return Err(e);
    }

    // Attribute the earliest failure across all ranks: min (ops, rank),
    // the same root-cause rule `Cluster::launch` applies in-process.
    let mut worst: Option<(u64, usize, String)> = None;
    for (rank, slot) in slots.iter().enumerate() {
        if let Some(Err(e)) = slot {
            let (ops, msg) = failure_parts(e);
            let better = match &worst {
                Some((wops, wrank, _)) => (ops, rank) < (*wops, *wrank),
                None => true,
            };
            if better {
                worst = Some((ops, rank, msg));
            }
        }
    }
    if let Some((ops, rank, msg)) = worst {
        broadcast_error(rank, ops, &msg);
        return Err(Error::RankFailure { rank, ops, msg });
    }

    let mut all = Vec::with_capacity(cfg.procs);
    for slot in slots {
        match slot {
            Some(Ok(v)) => all.push(v),
            _ => return Err(Error::Comm("result slot invariant violated".into())),
        }
    }
    let mut buf = Vec::new();
    write_seq(&all, &mut buf);
    for dst in 1..cfg.procs {
        let frame = encode_frame(0, dst as u32, TAG_RESULT_GO, 1, &buf);
        session.write_frame_to(dst, &frame)?;
    }
    Ok(all)
}

/// Run this process's rank of a `P`-rank TCP cluster: rendezvous, run `f`
/// through the standard launcher (so spans, kernel counters and failure
/// attribution behave identically to the channel fabric), then allgather —
/// **every** rank returns the identical rank-ordered `(result, metrics)`
/// vector, or the same attributed [`Error::RankFailure`].
pub fn run_tcp_hooked<M, R, F>(
    cfg: &TcpFabric,
    p: usize,
    progress: Option<Arc<dyn Progress>>,
    f: F,
) -> Result<Vec<(R, CommMetrics)>>
where
    M: Payload,
    R: Wire + Send,
    F: Fn(&mut Comm<M>) -> Result<R> + Sync,
{
    try_recv_guard()?;
    if p != cfg.procs {
        return Err(Error::Config(format!(
            "tcp fabric launched with --procs {} but this run wants {p} ranks",
            cfg.procs
        )));
    }
    let (transport, mut session) = establish::<M>(cfg)?;
    let comm = Comm::from_tcp(transport);
    let local: Result<(R, CommMetrics)> = match Cluster::launch(vec![comm], progress, f) {
        Ok(mut v) => {
            let (r, mut m) = v.pop().expect("one tcp rank");
            // Stamp the framing overhead at the same instant as every
            // other counter; the result/GO frames below are post-snapshot
            // and deliberately excluded.
            m.wire_overhead_bytes += session.overhead_bytes();
            Ok((r, m))
        }
        // The launcher saw a single-element vec, so it attributed the
        // failure to index 0 — rewrite to this process's cluster rank.
        Err(Error::RankFailure { ops, msg, .. }) => {
            Err(Error::RankFailure { rank: cfg.rank, ops, msg })
        }
        Err(e) => Err(e),
    };
    let out = exchange_results(&session, cfg, local);
    session.shutdown();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn free_port_addr() -> String {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let a = l.local_addr().unwrap().to_string();
        drop(l);
        a
    }

    #[test]
    fn frame_roundtrip_and_clean_eof() {
        let frame = encode_frame(3, 1, TAG_MSG, 1, &[9, 8, 7]);
        let mut cur = io::Cursor::new(frame);
        let got = read_frame(&mut cur).unwrap().unwrap();
        assert_eq!(
            got,
            RawFrame { src: 3, dst: 1, tag: TAG_MSG, control: 1, payload: vec![9, 8, 7] }
        );
        // Clean EOF at a frame boundary is end-of-stream, not an error.
        assert!(read_frame(&mut cur).unwrap().is_none());
        // Empty payload frames work too.
        let mut cur = io::Cursor::new(encode_frame(0, 2, TAG_RETIRE, 1, &[]));
        let got = read_frame(&mut cur).unwrap().unwrap();
        assert_eq!((got.tag, got.control, got.payload.len()), (TAG_RETIRE, 1, 0));
    }

    #[test]
    fn frame_truncation_and_oversize_are_comm_errors() {
        let full = encode_frame(1, 0, TAG_MSG, 0, &[1, 2, 3, 4, 5]);
        // Truncation at every interior cut — header or payload — is a
        // deterministic Comm error, never a panic or a hang.
        for cut in 1..full.len() {
            let mut cur = io::Cursor::new(full[..cut].to_vec());
            match read_frame(&mut cur) {
                Err(Error::Comm(_)) => {}
                other => panic!("cut={cut}: expected Comm error, got {other:?}"),
            }
        }
        // A length prefix beyond the cap fails before any allocation.
        let mut hdr = Vec::new();
        for w in [1u32, 0, TAG_MSG, 0, u32::MAX] {
            hdr.extend_from_slice(&w.to_le_bytes());
        }
        let mut cur = io::Cursor::new(hdr);
        match read_frame(&mut cur) {
            Err(Error::Comm(m)) => assert!(m.contains("exceeds"), "{m}"),
            other => panic!("expected oversize Comm error, got {other:?}"),
        }
    }

    #[test]
    fn hello_roundtrip_and_magic_version_gates() {
        let enc = encode_hello(0xDEAD_BEEF, 3, 8);
        let h = read_hello(&mut io::Cursor::new(enc.to_vec())).unwrap();
        assert_eq!(h, Hello { job_id: 0xDEAD_BEEF, rank: 3, procs: 8 });
        // Bad magic: a non-tricount peer is a Config error.
        let mut bad = enc;
        bad[0] ^= 0xFF;
        match read_hello(&mut io::Cursor::new(bad.to_vec())) {
            Err(Error::Config(m)) => assert!(m.contains("magic"), "{m}"),
            other => panic!("expected Config error, got {other:?}"),
        }
        // Version skew is a Config error naming both versions.
        let mut skew = encode_hello(1, 0, 2);
        skew[4] = 99;
        match read_hello(&mut io::Cursor::new(skew.to_vec())) {
            Err(Error::Config(m)) => assert!(m.contains("version"), "{m}"),
            other => panic!("expected Config error, got {other:?}"),
        }
        // A truncated hello is a wire fault.
        match read_hello(&mut io::Cursor::new(enc[..10].to_vec())) {
            Err(Error::Comm(_)) => {}
            other => panic!("expected Comm error, got {other:?}"),
        }
    }

    #[test]
    fn seq_roundtrip() {
        let items = vec![(1u64, String::from("a")), (2, String::from("bb"))];
        let mut buf = Vec::new();
        write_seq(&items, &mut buf);
        let mut rd = WireReader::new(&buf);
        let back = read_seq::<(u64, String)>(&mut rd).unwrap();
        rd.finish().unwrap();
        assert_eq!(back, items);
    }

    #[test]
    fn loopback_two_rank_transport_smoke() {
        let addr = free_port_addr();
        let cfg0 =
            TcpFabric { connect: addr.clone(), rank: 0, procs: 2, job_id: 0xAB, join_timeout_ms: 10_000 };
        let cfg1 = TcpFabric { connect: addr, rank: 1, procs: 2, job_id: 0xAB, join_timeout_ms: 10_000 };
        let worker = thread::spawn(move || {
            let (mut t, mut s) = establish::<Vec<u32>>(&cfg1).unwrap();
            t.send(0, Envelope { src: 1, control: false, msg: vec![7, 8, 9] }).unwrap();
            let sum = t.reduce_sum(5).unwrap();
            t.barrier().unwrap();
            t.retire(true);
            s.shutdown();
            sum
        });
        let (mut t, mut s) = establish::<Vec<u32>>(&cfg0).unwrap();
        let env = t.recv().unwrap();
        assert_eq!((env.src, env.control, env.msg), (1, false, vec![7, 8, 9]));
        let sum = t.reduce_sum(37).unwrap();
        t.barrier().unwrap();
        t.retire(true);
        s.shutdown();
        assert_eq!(sum, 42);
        assert_eq!(worker.join().unwrap(), 42);
    }

    fn ring_prog(c: &mut Comm<u64>) -> Result<u64> {
        let next = (c.rank() + 1) % c.size();
        c.send(next, (c.rank() as u64 + 1) * 10)?;
        let (_src, v) = c.recv()?;
        c.reduce_sum(v)
    }

    #[test]
    fn run_tcp_hooked_returns_full_allgather_on_every_rank() {
        let addr = free_port_addr();
        let cfg1 =
            TcpFabric { connect: addr.clone(), rank: 1, procs: 2, job_id: 7, join_timeout_ms: 10_000 };
        let cfg0 = TcpFabric { connect: addr, rank: 0, procs: 2, job_id: 7, join_timeout_ms: 10_000 };
        let worker = thread::spawn(move || run_tcp_hooked::<u64, u64, _>(&cfg1, 2, None, ring_prog));
        let r0 = run_tcp_hooked::<u64, u64, _>(&cfg0, 2, None, ring_prog).unwrap();
        let r1 = worker.join().unwrap().unwrap();
        // Both ranks: 10 + 20 reduced on each side.
        assert_eq!(r0.len(), 2);
        assert_eq!(r1.len(), 2);
        assert_eq!(r0[0].0, 30);
        assert_eq!(r0[1].0, 30);
        // The allgather is *identical* on every rank, counter for counter.
        for (a, b) in r0.iter().zip(&r1) {
            assert_eq!(a.0, b.0);
            assert_eq!(a.1.messages_sent, b.1.messages_sent);
            assert_eq!(a.1.bytes_sent, b.1.bytes_sent);
            assert_eq!(a.1.wire_overhead_bytes, b.1.wire_overhead_bytes);
        }
        // Framing overhead is visible on a socket fabric.
        assert!(r0[0].1.wire_overhead_bytes > 0, "{:?}", r0[0].1.wire_overhead_bytes);
    }

    #[test]
    fn run_tcp_hooked_attributes_failures_across_processes() {
        let addr = free_port_addr();
        let cfg1 =
            TcpFabric { connect: addr.clone(), rank: 1, procs: 2, job_id: 9, join_timeout_ms: 10_000 };
        let cfg0 = TcpFabric { connect: addr, rank: 0, procs: 2, job_id: 9, join_timeout_ms: 10_000 };
        let prog = |c: &mut Comm<u64>| -> Result<u64> {
            if c.rank() == 1 {
                Err(Error::Cluster("injected worker failure".into()))
            } else {
                Ok(1)
            }
        };
        let worker = thread::spawn(move || run_tcp_hooked::<u64, u64, _>(&cfg1, 2, None, prog));
        let r0 = run_tcp_hooked::<u64, u64, _>(&cfg0, 2, None, prog);
        let r1 = worker.join().unwrap();
        for r in [r0, r1] {
            match r {
                Err(Error::RankFailure { rank, msg, .. }) => {
                    assert_eq!(rank, 1);
                    assert!(msg.contains("injected worker failure"), "{msg}");
                }
                other => panic!("expected rank 1's failure on both ranks, got {other:?}"),
            }
        }
    }

    #[test]
    fn rendezvous_rejects_garbage_hello() {
        let addr = free_port_addr();
        let cfg0 =
            TcpFabric { connect: addr.clone(), rank: 0, procs: 2, job_id: 1, join_timeout_ms: 10_000 };
        let host = thread::spawn(move || establish::<u64>(&cfg0));
        // Dial the rendezvous and present 24 bytes of garbage.
        let mut s = loop {
            match TcpStream::connect(addr.as_str()) {
                Ok(s) => break s,
                Err(_) => thread::sleep(Duration::from_millis(10)),
            }
        };
        s.write_all(&[0xAAu8; HELLO_BYTES]).unwrap();
        match host.join().unwrap() {
            Err(Error::Config(m)) => assert!(m.contains("magic"), "{m}"),
            other => panic!("expected Config error at rank 0, got {:?}", other.err()),
        }
    }

    #[test]
    fn single_rank_tcp_cluster_is_trivial() {
        let cfg = TcpFabric {
            connect: "127.0.0.1:1".into(), // never dialed at P=1
            rank: 0,
            procs: 1,
            job_id: 3,
            join_timeout_ms: 1000,
        };
        let out = run_tcp_hooked::<u64, u64, _>(&cfg, 1, None, |c| c.reduce_sum(7)).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].0, 7);
        assert_eq!(out[0].1.wire_overhead_bytes, 0);
    }
}
