//! Threaded message-passing backend — the crate's "MPI".
//!
//! Each of the `P` ranks runs on its own OS thread with private state;
//! ranks communicate **only** through typed point-to-point channels plus a
//! barrier, mirroring the paper's distributed-memory model (§II Computation
//! Model). No rank reads another rank's partition; the dynamic-LB algorithm
//! shares the graph read-only via `Arc`, which is faithful to §V's
//! assumption that every machine stores the whole network.
//!
//! The API is deliberately MPI-shaped: `send`, `try_recv`, `recv_timeout`,
//! `barrier`, `reduce_sum` — so the algorithm modules read like the paper's
//! pseudocode.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Barrier, Mutex};
use std::time::{Duration, Instant};

use crate::comm::metrics::CommMetrics;
use crate::error::{Error, Result};

/// Default guard against protocol deadlocks in tests/CI.
pub const RECV_DEADLOCK_GUARD: Duration = Duration::from_secs(30);

/// Messages must declare their wire size so the metrics layer can account
/// bytes the way the paper reasons about them (neighbor-list words).
pub trait Payload: Send + 'static {
    /// Serialized size in bytes if this were on an MPI wire.
    fn size_bytes(&self) -> u64;
}

struct Shared {
    barrier: Barrier,
    reduce_cells: Mutex<Vec<u64>>,
    reduce_acc: AtomicU64,
}

/// A rank's endpoint: its id, channels to every peer, and its metrics.
pub struct Comm<M: Payload> {
    rank: usize,
    size: usize,
    senders: Vec<Sender<(usize, M)>>,
    receiver: Receiver<(usize, M)>,
    shared: Arc<Shared>,
    /// Per-rank counters, returned to the driver by [`Cluster::run`].
    pub metrics: CommMetrics,
}

impl<M: Payload> Comm<M> {
    /// This rank's id in `0..size`.
    #[inline]
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Number of ranks `P`.
    #[inline]
    pub fn size(&self) -> usize {
        self.size
    }

    /// Point-to-point send (asynchronous, unbounded buffering — MPI eager
    /// mode). Sending to self is allowed (delivered through the queue).
    pub fn send(&mut self, dst: usize, msg: M) -> Result<()> {
        self.metrics.messages_sent += 1;
        self.metrics.bytes_sent += msg.size_bytes();
        self.senders[dst]
            .send((self.rank, msg))
            .map_err(|_| Error::Cluster(format!("rank {} send to dead rank {dst}", self.rank)))
    }

    /// Control-plane send (completion notifiers, task protocol): accounted
    /// separately from data messages.
    pub fn send_control(&mut self, dst: usize, msg: M) -> Result<()> {
        self.metrics.control_sent += 1;
        self.senders[dst]
            .send((self.rank, msg))
            .map_err(|_| Error::Cluster(format!("rank {} send to dead rank {dst}", self.rank)))
    }

    /// Broadcast a control message to every other rank via `clone_fn`.
    pub fn bcast_control(&mut self, make: impl Fn() -> M) -> Result<()> {
        for dst in 0..self.size {
            if dst != self.rank {
                self.send_control(dst, make())?;
            }
        }
        Ok(())
    }

    /// Non-blocking receive.
    pub fn try_recv(&mut self) -> Option<(usize, M)> {
        match self.receiver.try_recv() {
            Ok(x) => {
                self.metrics.messages_received += 1;
                Some(x)
            }
            Err(_) => None,
        }
    }

    /// Blocking receive with the deadlock guard; records wait time as idle.
    pub fn recv(&mut self) -> Result<(usize, M)> {
        let start = Instant::now();
        let r = self.receiver.recv_timeout(RECV_DEADLOCK_GUARD);
        self.metrics.recv_wait += start.elapsed();
        match r {
            Ok(x) => {
                self.metrics.messages_received += 1;
                Ok(x)
            }
            Err(RecvTimeoutError::Timeout) => Err(Error::Cluster(format!(
                "rank {} recv timed out after {RECV_DEADLOCK_GUARD:?} (protocol deadlock?)",
                self.rank
            ))),
            Err(RecvTimeoutError::Disconnected) => {
                Err(Error::Cluster(format!("rank {} peers disconnected", self.rank)))
            }
        }
    }

    /// Synchronize all ranks (MPI_Barrier).
    pub fn barrier(&self) {
        self.shared.barrier.wait();
    }

    /// Sum-reduce a u64 across all ranks; everyone receives the total
    /// (MPI_Allreduce(SUM)). Internally: write cell → barrier → read.
    pub fn reduce_sum(&self, value: u64) -> u64 {
        {
            let mut cells = self.shared.reduce_cells.lock().unwrap();
            cells[self.rank] = value;
        }
        self.shared.barrier.wait();
        if self.rank == 0 {
            let cells = self.shared.reduce_cells.lock().unwrap();
            let sum = cells.iter().sum();
            self.shared.reduce_acc.store(sum, Ordering::SeqCst);
        }
        self.shared.barrier.wait();
        self.shared.reduce_acc.load(Ordering::SeqCst)
    }
}

/// The cluster launcher: spawns `P` rank threads and runs `f` on each.
pub struct Cluster;

impl Cluster {
    /// Run `f(rank_comm)` on `p` ranks; returns each rank's result and its
    /// metrics, indexed by rank. Propagates rank panics as [`Error::Cluster`].
    pub fn run<M, R, F>(p: usize, f: F) -> Result<Vec<(R, CommMetrics)>>
    where
        M: Payload,
        R: Send,
        F: Fn(&mut Comm<M>) -> R + Sync,
    {
        assert!(p >= 1, "cluster needs at least one rank");
        let mut senders = Vec::with_capacity(p);
        let mut receivers = Vec::with_capacity(p);
        for _ in 0..p {
            let (tx, rx) = std::sync::mpsc::channel();
            senders.push(tx);
            receivers.push(rx);
        }
        let shared = Arc::new(Shared {
            barrier: Barrier::new(p),
            reduce_cells: Mutex::new(vec![0; p]),
            reduce_acc: AtomicU64::new(0),
        });

        let mut comms: Vec<Comm<M>> = receivers
            .into_iter()
            .enumerate()
            .map(|(rank, receiver)| Comm {
                rank,
                size: p,
                senders: senders.clone(),
                receiver,
                shared: shared.clone(),
                metrics: CommMetrics::default(),
            })
            .collect();
        drop(senders);

        let f = &f;
        let results: Vec<std::thread::Result<(R, CommMetrics)>> = std::thread::scope(|s| {
            let handles: Vec<_> = comms
                .drain(..)
                .map(|mut comm| {
                    s.spawn(move || {
                        let start = Instant::now();
                        let r = f(&mut comm);
                        comm.metrics.total = start.elapsed();
                        (r, comm.metrics)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join()).collect()
        });

        let mut out = Vec::with_capacity(p);
        for (rank, r) in results.into_iter().enumerate() {
            match r {
                Ok(x) => out.push(x),
                Err(e) => {
                    let msg = e
                        .downcast_ref::<String>()
                        .cloned()
                        .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                        .unwrap_or_else(|| "unknown panic".into());
                    return Err(Error::Cluster(format!("rank {rank} panicked: {msg}")));
                }
            }
        }
        Ok(out)
    }
}

impl Payload for Vec<u32> {
    fn size_bytes(&self) -> u64 {
        (self.len() * 4) as u64
    }
}

impl Payload for u64 {
    fn size_bytes(&self) -> u64 {
        8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_pass() {
        // Each rank sends its rank² to the next; sums must match.
        let res = Cluster::run::<u64, u64, _>(4, |c| {
            let next = (c.rank() + 1) % c.size();
            c.send(next, (c.rank() * c.rank()) as u64).unwrap();
            let (_src, v) = c.recv().unwrap();
            v
        })
        .unwrap();
        let mut got: Vec<u64> = res.iter().map(|(v, _)| *v).collect();
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 4, 9]);
    }

    #[test]
    fn reduce_sum_all_ranks_see_total() {
        let res = Cluster::run::<u64, u64, _>(5, |c| c.reduce_sum(c.rank() as u64 + 1)).unwrap();
        for (v, _) in res {
            assert_eq!(v, 15);
        }
    }

    #[test]
    fn metrics_count_messages_and_bytes() {
        let res = Cluster::run::<Vec<u32>, (), _>(2, |c| {
            if c.rank() == 0 {
                c.send(1, vec![1, 2, 3]).unwrap();
            } else {
                let (src, msg) = c.recv().unwrap();
                assert_eq!(src, 0);
                assert_eq!(msg, vec![1, 2, 3]);
            }
        })
        .unwrap();
        assert_eq!(res[0].1.messages_sent, 1);
        assert_eq!(res[0].1.bytes_sent, 12);
        assert_eq!(res[1].1.messages_received, 1);
    }

    #[test]
    fn barrier_orders_phases() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let phase1 = Arc::new(AtomicUsize::new(0));
        let p1 = phase1.clone();
        Cluster::run::<u64, (), _>(4, move |c| {
            p1.fetch_add(1, Ordering::SeqCst);
            c.barrier();
            // After the barrier every rank must observe all 4 increments.
            assert_eq!(p1.load(Ordering::SeqCst), 4);
        })
        .unwrap();
    }

    #[test]
    fn single_rank_cluster() {
        let res = Cluster::run::<u64, u64, _>(1, |c| c.reduce_sum(7)).unwrap();
        assert_eq!(res[0].0, 7);
    }

    #[test]
    fn rank_panic_is_reported() {
        let r = Cluster::run::<u64, (), _>(2, |c| {
            if c.rank() == 1 {
                panic!("injected fault");
            }
        });
        match r {
            Err(Error::Cluster(msg)) => assert!(msg.contains("injected fault"), "{msg}"),
            other => panic!("expected cluster error, got {other:?}"),
        }
    }

    #[test]
    fn self_send_delivered() {
        Cluster::run::<u64, (), _>(2, |c| {
            let me = c.rank();
            c.send(me, 99).unwrap();
            let (src, v) = c.recv().unwrap();
            assert_eq!((src, v), (me, 99));
        })
        .unwrap();
    }
}
