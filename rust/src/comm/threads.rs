//! Threaded message-passing backend — the crate's "MPI".
//!
//! Each of the `P` ranks runs on its own OS thread with private state;
//! ranks communicate **only** through a [`Transport`] endpoint plus a
//! barrier, mirroring the paper's distributed-memory model (§II Computation
//! Model). No rank reads another rank's partition; the dynamic-LB algorithm
//! shares the graph read-only via `Arc`, which is faithful to §V's
//! assumption that every machine stores the whole network.
//!
//! The API is deliberately MPI-shaped: `send`, `try_recv`, `recv_timeout`,
//! `barrier`, `reduce_sum` — so the algorithm modules read like the paper's
//! pseudocode. [`Comm`] owns the per-rank metrics and dispatches every
//! operation to one of two fabrics behind the [`Transport`] trait
//! (`comm::transport`): the production [`ChannelTransport`] (the default —
//! `Cluster::run`/`try_run` are byte-for-byte the seed behavior), or the
//! seeded deterministic `testkit::sim` fabric the conformance suite drives
//! adversarial schedules through (DESIGN.md §10).

use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

use crate::adj::stats as kernel_stats;
use crate::comm::metrics::CommMetrics;
use crate::comm::tcp::TcpTransport;
use crate::comm::transport::{
    channel_fabric, ChannelTransport, Envelope, Liveness, RetryPolicy, Transport,
};
use crate::error::{Error, Result};
use crate::obs::span::{SpanPhase, SpanRecorder};
use crate::testkit::sim::VirtualEndpoint;

pub use crate::comm::transport::Payload;

/// Default guard against protocol deadlocks in tests/CI. Override with the
/// `TRICOUNT_RECV_GUARD_SECS` env var (whole seconds, > 0) for large-graph
/// CI and local stress runs that legitimately block longer than 30s.
pub const RECV_DEADLOCK_GUARD: Duration = Duration::from_secs(30);

/// The effective guard: `TRICOUNT_RECV_GUARD_SECS` if set and valid, else
/// [`RECV_DEADLOCK_GUARD`]. Read once and cached for the process. This is
/// the *infallible* reader used on the transport hot path; an invalid
/// override falls back to the default here but is surfaced as
/// [`Error::Config`] by [`try_recv_guard`], which every cluster entry
/// point calls before launching — so a typo fails the run at startup
/// instead of silently running with a 30s guard.
pub fn recv_guard() -> Duration {
    static GUARD: OnceLock<Duration> = OnceLock::new();
    *GUARD.get_or_init(|| {
        guard_from(std::env::var("TRICOUNT_RECV_GUARD_SECS").ok().as_deref())
            .unwrap_or(RECV_DEADLOCK_GUARD)
    })
}

/// Validate the `TRICOUNT_RECV_GUARD_SECS` override: `Error::Config` on
/// anything that is not a positive whole number of seconds. Called at
/// cluster startup ([`Cluster::try_run`], the sim launcher, the CLI) so
/// a bad value fails fast; the validated duration is the single timeout
/// the deadline machinery ([`crate::comm::transport::RetryPolicy`],
/// `Transport::recv_deadline`) derives from.
pub fn try_recv_guard() -> Result<Duration> {
    guard_from(std::env::var("TRICOUNT_RECV_GUARD_SECS").ok().as_deref())
}

/// Parse an override value (factored out of the readers so the policy is
/// testable without racing on process-global env state). Missing ⇒ the
/// default; present but invalid or zero ⇒ `Error::Config`.
fn guard_from(val: Option<&str>) -> Result<Duration> {
    match val {
        None => Ok(RECV_DEADLOCK_GUARD),
        Some(s) => match s.trim().parse::<u64>() {
            Ok(secs) if secs > 0 => Ok(Duration::from_secs(secs)),
            _ => Err(Error::Config(format!(
                "TRICOUNT_RECV_GUARD_SECS=`{s}` is not a positive whole number of seconds"
            ))),
        },
    }
}

/// A unit of checkpointable progress (`ft/checkpoint`): a vertex range or
/// a task, identified independently of which rank computes it — that is
/// what lets recovery re-attribute a dead rank's units to survivors.
/// `kind` namespaces the key space per protocol (range vs task vs batch).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct ProgressUnit {
    pub kind: u8,
    pub lo: u32,
    pub hi: u32,
}

impl ProgressUnit {
    /// A §IV vertex-range unit `[lo, hi)`.
    pub fn range(lo: u32, hi: u32) -> Self {
        ProgressUnit { kind: 0, lo, hi }
    }

    /// A §V task unit (start, start+len).
    pub fn task(start: u32, len: u32) -> Self {
        ProgressUnit { kind: 1, lo: start, hi: start.saturating_add(len) }
    }

    /// A stream batch unit (batch index).
    pub fn batch(index: u32) -> Self {
        ProgressUnit { kind: 2, lo: index, hi: index + 1 }
    }
}

/// Checkpoint sink installed on every [`Comm`] of a supervised run
/// (`ft::checkpoint::CheckpointStore` implements it). Publications happen
/// at phase boundaries from rank threads; implementations synchronize
/// internally. When no sink is installed (every unsupervised run) the
/// hooks are a single `Option` test — the fault-free overhead the
/// `ft_overhead` CI gate bounds.
pub trait Progress: Send + Sync {
    /// Publish a *monotone partial* sum for a unit: a guaranteed-disjoint
    /// contribution keyed by the contributing rank (overwrites that
    /// rank's previous partial for the unit).
    fn partial(&self, rank: usize, unit: ProgressUnit, sum: u64);

    /// Acknowledge a unit as fully resolved with its exact final sum.
    fn ack(&self, rank: usize, unit: ProgressUnit, sum: u64);
}

/// The fabric a [`Comm`] runs over. An enum (not a box), and every call
/// dispatches through a per-variant `match` (the [`with_transport!`]
/// macro) rather than a trait object, so the channel path keeps genuine
/// static dispatch — the seed's channel code with one predictable branch
/// in front, no vtable on the hot path.
enum Backend<M: Payload> {
    Channel(ChannelTransport<M>),
    Virtual(VirtualEndpoint<M>),
    Tcp(TcpTransport<M>),
}

/// Statically dispatch one [`Transport`] call to the active variant.
macro_rules! with_transport {
    ($backend:expr, $t:ident => $call:expr) => {
        match $backend {
            Backend::Channel($t) => $call,
            Backend::Virtual($t) => $call,
            Backend::Tcp($t) => $call,
        }
    };
}

/// A rank's endpoint: its transport, its metrics and its span timeline.
pub struct Comm<M: Payload> {
    backend: Backend<M>,
    /// Per-rank counters, returned to the driver by [`Cluster::run`].
    pub metrics: CommMetrics,
    /// Per-rank phase-span recorder (`obs::span`): wall clock on the
    /// channel fabric, the scheduler's virtual clock on the testkit
    /// fabric. Every blocking comm op records its span automatically;
    /// algorithms mark compute sections via [`Comm::span_begin`] /
    /// [`Comm::span_end`]. Harvested into `CommMetrics::spans` by the
    /// launcher when the rank program returns.
    pub spans: SpanRecorder,
    /// Checkpoint sink of the supervising `ft/` run, if any — installed
    /// by the launcher; `None` (one branch per checkpoint call) on every
    /// unsupervised run.
    progress: Option<Arc<dyn Progress>>,
}

impl<M: Payload> Comm<M> {
    pub(crate) fn from_channel(t: ChannelTransport<M>) -> Self {
        Comm {
            backend: Backend::Channel(t),
            metrics: CommMetrics::default(),
            spans: SpanRecorder::wall(),
            progress: None,
        }
    }

    pub(crate) fn from_virtual(t: VirtualEndpoint<M>) -> Self {
        Comm {
            backend: Backend::Virtual(t),
            metrics: CommMetrics::default(),
            spans: SpanRecorder::virtual_clock(),
            progress: None,
        }
    }

    /// Endpoint over the socket fabric (`comm::tcp`): wall-clock spans,
    /// exactly like the channel fabric — the wire is the only difference.
    pub(crate) fn from_tcp(t: TcpTransport<M>) -> Self {
        Comm {
            backend: Backend::Tcp(t),
            metrics: CommMetrics::default(),
            spans: SpanRecorder::wall(),
            progress: None,
        }
    }

    /// Publish a monotone partial sum for a unit (no-op unsupervised).
    #[inline]
    pub fn ckpt_partial(&self, unit: ProgressUnit, sum: u64) {
        if let Some(p) = &self.progress {
            p.partial(self.rank(), unit, sum);
        }
    }

    /// Acknowledge a unit as fully resolved (no-op unsupervised).
    #[inline]
    pub fn ckpt_ack(&self, unit: ProgressUnit, sum: u64) {
        if let Some(p) = &self.progress {
            p.ack(self.rank(), unit, sum);
        }
    }

    /// Current tick in this rank's clock domain: µs since launch on the
    /// channel fabric, the scheduler's virtual clock on the sim fabric.
    #[inline]
    fn ticks(&self) -> u64 {
        match &self.backend {
            Backend::Channel(_) | Backend::Tcp(_) => self.spans.wall_now(),
            Backend::Virtual(t) => t.virtual_now().unwrap_or(0),
        }
    }

    /// Open a phase span (typically [`SpanPhase::Compute`] around a
    /// counting section) on this rank's timeline; close it with
    /// [`Comm::span_end`]. Spans nest LIFO.
    pub fn span_begin(&mut self, phase: SpanPhase) {
        let t = self.ticks();
        self.spans.begin_at(phase, t);
    }

    /// Close the most recently opened span.
    pub fn span_end(&mut self) {
        let t = self.ticks();
        self.spans.end_at(t);
    }

    /// This rank's id in `0..size`.
    #[inline]
    pub fn rank(&self) -> usize {
        with_transport!(&self.backend, t => t.rank())
    }

    /// Number of ranks `P`.
    #[inline]
    pub fn size(&self) -> usize {
        with_transport!(&self.backend, t => t.size())
    }

    /// Point-to-point send (asynchronous, unbounded buffering — MPI eager
    /// mode). Sending to self is allowed (delivered through the queue).
    pub fn send(&mut self, dst: usize, msg: M) -> Result<()> {
        self.metrics.messages_sent += 1;
        self.metrics.bytes_sent += msg.size_bytes();
        self.metrics.transport_ops += 1;
        let src = self.rank();
        let t0 = self.ticks();
        let r = with_transport!(&mut self.backend, t => t.send(dst, Envelope { src, control: false, msg }));
        let t1 = self.ticks();
        self.spans.record(SpanPhase::Send, t0, t1);
        r
    }

    /// Control-plane send (completion notifiers, task protocol): accounted
    /// separately from data messages, on both endpoints.
    pub fn send_control(&mut self, dst: usize, msg: M) -> Result<()> {
        self.metrics.control_sent += 1;
        self.metrics.transport_ops += 1;
        let src = self.rank();
        let t0 = self.ticks();
        let r = with_transport!(&mut self.backend, t => t.send(dst, Envelope { src, control: true, msg }));
        let t1 = self.ticks();
        self.spans.record(SpanPhase::Send, t0, t1);
        r
    }

    /// Broadcast a control message to every other rank via `make`.
    pub fn bcast_control(&mut self, make: impl Fn() -> M) -> Result<()> {
        for dst in 0..self.size() {
            if dst != self.rank() {
                self.send_control(dst, make())?;
            }
        }
        Ok(())
    }

    /// Account one delivered envelope and unwrap it.
    #[inline]
    fn accept(&mut self, env: Envelope<M>) -> (usize, M) {
        if env.control {
            self.metrics.control_received += 1;
        } else {
            self.metrics.messages_received += 1;
        }
        (env.src, env.msg)
    }

    /// Non-blocking receive.
    pub fn try_recv(&mut self) -> Option<(usize, M)> {
        self.metrics.transport_ops += 1;
        let env = with_transport!(&mut self.backend, t => t.try_recv())?;
        Some(self.accept(env))
    }

    /// Blocking receive with the deadlock guard; records wait time as idle.
    /// On the channel fabric the guard is [`recv_guard`] wall-clock; on the
    /// virtual fabric it is exact deadlock detection under virtual time —
    /// and `recv_wait` itself is measured in *virtual ticks* there (1 tick
    /// ↔ 1 µs), so the wait is deterministic under a replayed schedule.
    pub fn recv(&mut self) -> Result<(usize, M)> {
        self.metrics.transport_ops += 1;
        let t0 = self.ticks();
        let start =
            matches!(self.backend, Backend::Channel(_) | Backend::Tcp(_)).then(Instant::now);
        let r = with_transport!(&mut self.backend, t => t.recv());
        let t1 = self.ticks();
        self.metrics.recv_wait += match start {
            Some(s) => s.elapsed(),
            None => Duration::from_micros(t1.saturating_sub(t0)),
        };
        self.spans.record(SpanPhase::RecvWait, t0, t1);
        r.map(|env| self.accept(env))
    }

    /// Blocking receive bounded by an explicit deadline (`ft/` transport
    /// hardening): `Ok(None)` when it expires undelivered — wall time on
    /// the channel fabric, deterministic virtual time on the sim fabric —
    /// so request/reply protocols can retry with [`RetryPolicy`] backoff
    /// instead of tripping the recv guard.
    pub fn recv_deadline(&mut self, d: Duration) -> Result<Option<(usize, M)>> {
        self.metrics.transport_ops += 1;
        let t0 = self.ticks();
        let start =
            matches!(self.backend, Backend::Channel(_) | Backend::Tcp(_)).then(Instant::now);
        let r = with_transport!(&mut self.backend, t => t.recv_deadline(d));
        let t1 = self.ticks();
        self.metrics.recv_wait += match start {
            Some(s) => s.elapsed(),
            None => Duration::from_micros(t1.saturating_sub(t0)),
        };
        self.spans.record(SpanPhase::RecvWait, t0, t1);
        r.map(|env| env.map(|e| self.accept(e)))
    }

    /// Classify a peer off the fabric's liveness board. Staleness
    /// threshold = half the recv guard: a rank silent that long while
    /// the board still says "running" reads as [`Liveness::Slow`].
    pub fn liveness_of(&self, rank: usize) -> Liveness {
        with_transport!(&self.backend, t => t.liveness(rank, recv_guard() / 2))
    }

    /// Bounded-retry receive for request/reply protocols: wait under the
    /// policy's backed-off deadlines, calling `resend` to retransmit the
    /// request before each retry. Returns `Ok(None)` when retries exhaust
    /// against a peer the liveness board still calls alive (caller
    /// decides: a lost control message vs a straggler), and `Err` as soon
    /// as the board says the peer is dead.
    pub fn recv_retry(
        &mut self,
        peer: usize,
        policy: &RetryPolicy,
        mut resend: impl FnMut(&mut Self) -> Result<()>,
    ) -> Result<Option<(usize, M)>> {
        for attempt in 0..=policy.max_retries {
            if let Some(got) = self.recv_deadline(policy.deadline_for(attempt))? {
                return Ok(Some(got));
            }
            if self.liveness_of(peer) == Liveness::Dead {
                return Err(Error::Cluster(format!(
                    "rank {}: peer rank {peer} is dead (liveness board) after {attempt} retries",
                    self.rank()
                )));
            }
            if attempt < policy.max_retries {
                self.metrics.retries += 1;
                resend(self)?;
            }
        }
        Ok(None)
    }

    /// Synchronize all ranks (MPI_Barrier). Fails instead of hanging when
    /// the fabric can prove completion impossible (virtual fabric only).
    pub fn barrier(&mut self) -> Result<()> {
        self.metrics.transport_ops += 1;
        let t0 = self.ticks();
        let r = with_transport!(&mut self.backend, t => t.barrier());
        let t1 = self.ticks();
        self.spans.record(SpanPhase::Barrier, t0, t1);
        r
    }

    /// Sum-reduce a u64 across all ranks; everyone receives the total
    /// (MPI_Allreduce(SUM)).
    pub fn reduce_sum(&mut self, value: u64) -> Result<u64> {
        self.metrics.transport_ops += 1;
        let t0 = self.ticks();
        let r = with_transport!(&mut self.backend, t => t.reduce_sum(value));
        let t1 = self.ticks();
        self.spans.record(SpanPhase::Reduce, t0, t1);
        r
    }

    /// Stamp end-of-run metrics once the rank program has returned: the
    /// run's `total` (virtual ticks → µs on the sim fabric, so replays
    /// agree; wall time otherwise), the per-rank kernel mix, and the span
    /// log. Called by the launcher while the rank still holds the
    /// scheduler token, so every reading is deterministic.
    fn finish(&mut self, start: Instant, kernels: &kernel_stats::RankKernelCounters) {
        self.metrics.total = match &self.backend {
            Backend::Channel(_) | Backend::Tcp(_) => start.elapsed(),
            Backend::Virtual(t) => Duration::from_micros(t.virtual_now().unwrap_or(0)),
        };
        self.metrics.kernel = kernels.snapshot();
        self.metrics.spans = self.spans.take_log();
    }
}

/// The cluster launcher: spawns `P` rank threads and runs `f` on each.
pub struct Cluster;

impl Cluster {
    /// Run `f(rank_comm)` on `p` ranks over the channel fabric; returns
    /// each rank's result and its metrics, indexed by rank. Propagates rank
    /// panics as [`Error::Cluster`].
    pub fn run<M, R, F>(p: usize, f: F) -> Result<Vec<(R, CommMetrics)>>
    where
        M: Payload,
        R: Send,
        F: Fn(&mut Comm<M>) -> R + Sync,
    {
        Self::try_run(p, |c| Ok(f(c)))
    }

    /// [`Cluster::run`] for fallible rank programs: a rank returning `Err`
    /// surfaces as that [`Error`] from the whole run (lowest rank wins when
    /// several fail) instead of poisoning the cluster with a panic. All
    /// ranks are still joined before returning; a peer blocked on a rank
    /// that bailed out is bounded by the [`recv_guard`] timeout and then
    /// fails with its own `Err`.
    pub fn try_run<M, R, F>(p: usize, f: F) -> Result<Vec<(R, CommMetrics)>>
    where
        M: Payload,
        R: Send,
        F: Fn(&mut Comm<M>) -> Result<R> + Sync,
    {
        assert!(p >= 1, "cluster needs at least one rank");
        try_recv_guard()?;
        let comms = channel_fabric(p).into_iter().map(Comm::from_channel).collect();
        Self::launch(comms, None, f)
    }

    /// [`Cluster::try_run`] with an `ft/` checkpoint sink installed on
    /// every rank's [`Comm`] — the supervised entry point.
    pub fn try_run_supervised<M, R, F>(
        p: usize,
        progress: Option<Arc<dyn Progress>>,
        f: F,
    ) -> Result<Vec<(R, CommMetrics)>>
    where
        M: Payload,
        R: Send,
        F: Fn(&mut Comm<M>) -> Result<R> + Sync,
    {
        assert!(p >= 1, "cluster needs at least one rank");
        try_recv_guard()?;
        let comms = channel_fabric(p).into_iter().map(Comm::from_channel).collect();
        Self::launch(comms, progress, f)
    }

    /// Spawn one thread per pre-built endpoint, run `f`, join, and fold
    /// panics/errors. Shared by [`Cluster::try_run`] (channel fabric) and
    /// `testkit::sim::try_run_sim` (virtual fabric).
    ///
    /// Failure attribution: *all* rank results are collected first, then
    /// the failure with the **lowest transport-op count** is reported
    /// (ties broken by rank id). A dead rank makes its peers fail too,
    /// later in protocol time — joining in rank order and returning the
    /// first `Err` would blame whichever victim happens to have the
    /// lowest rank id, not the root cause. Panicking ranks have no
    /// metrics, so they report op count 0 — a panic is never a
    /// downstream symptom of another rank's failure.
    pub(crate) fn launch<M, R, F>(
        mut comms: Vec<Comm<M>>,
        progress: Option<Arc<dyn Progress>>,
        f: F,
    ) -> Result<Vec<(R, CommMetrics)>>
    where
        M: Payload,
        R: Send,
        F: Fn(&mut Comm<M>) -> Result<R> + Sync,
    {
        let p = comms.len();
        let f = &f;
        let progress = &progress;
        let results: Vec<std::thread::Result<(Result<R>, CommMetrics)>> =
            std::thread::scope(|s| {
                let handles: Vec<_> = comms
                    .drain(..)
                    .map(|mut comm| {
                        s.spawn(move || {
                            // Per-rank kernel sink: bumps from this thread
                            // land in `kernels` (and the global sum) until
                            // the scope guard drops at thread exit.
                            let kernels =
                                Arc::new(kernel_stats::RankKernelCounters::default());
                            let _scope = kernel_stats::install_rank(kernels.clone());
                            comm.progress = progress.clone();
                            with_transport!(&mut comm.backend, t => t.start());
                            // Re-anchor wall span ticks at thread start so
                            // they share a time origin with `total` below
                            // (the endpoints were built pre-spawn).
                            comm.spans.reset_epoch();
                            let start = Instant::now();
                            let r = f(&mut comm);
                            with_transport!(&mut comm.backend, t => t.retire(r.is_ok()));
                            comm.finish(start, &kernels);
                            (r, std::mem::take(&mut comm.metrics))
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join()).collect()
            });

        let mut out = Vec::with_capacity(p);
        // (ops, rank, error) of every failure; report min by (ops, rank).
        let mut failures: Vec<(u64, usize, Error)> = Vec::new();
        for (rank, r) in results.into_iter().enumerate() {
            match r {
                Ok((Ok(x), m)) => out.push((x, m)),
                Ok((Err(e), m)) => {
                    let msg = match e {
                        Error::Cluster(m) => m,
                        Error::RankFailure { msg, .. } => msg,
                        other => other.to_string(),
                    };
                    failures.push((
                        m.transport_ops,
                        rank,
                        Error::RankFailure { rank, ops: m.transport_ops, msg },
                    ));
                }
                Err(e) => {
                    let msg = e
                        .downcast_ref::<String>()
                        .cloned()
                        .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                        .unwrap_or_else(|| "unknown panic".into());
                    failures.push((
                        0,
                        rank,
                        Error::RankFailure { rank, ops: 0, msg: format!("panicked: {msg}") },
                    ));
                }
            }
        }
        if let Some(pos) = failures
            .iter()
            .enumerate()
            .min_by_key(|(_, (ops, rank, _))| (*ops, *rank))
            .map(|(i, _)| i)
        {
            return Err(failures.swap_remove(pos).2);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn ring_pass() {
        // Each rank sends its rank² to the next; sums must match.
        let res = Cluster::run::<u64, u64, _>(4, |c| {
            let next = (c.rank() + 1) % c.size();
            c.send(next, (c.rank() * c.rank()) as u64).unwrap();
            let (_src, v) = c.recv().unwrap();
            v
        })
        .unwrap();
        let mut got: Vec<u64> = res.iter().map(|(v, _)| *v).collect();
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 4, 9]);
    }

    #[test]
    fn reduce_sum_all_ranks_see_total() {
        let res =
            Cluster::run::<u64, u64, _>(5, |c| c.reduce_sum(c.rank() as u64 + 1).unwrap()).unwrap();
        for (v, _) in res {
            assert_eq!(v, 15);
        }
    }

    #[test]
    fn metrics_count_messages_and_bytes() {
        let res = Cluster::run::<Vec<u32>, (), _>(2, |c| {
            if c.rank() == 0 {
                c.send(1, vec![1, 2, 3]).unwrap();
            } else {
                let (src, msg) = c.recv().unwrap();
                assert_eq!(src, 0);
                assert_eq!(msg, vec![1, 2, 3]);
            }
        })
        .unwrap();
        assert_eq!(res[0].1.messages_sent, 1);
        assert_eq!(res[0].1.bytes_sent, 12);
        assert_eq!(res[1].1.messages_received, 1);
    }

    #[test]
    fn control_receives_accounted_apart_from_data() {
        let res = Cluster::run::<u64, (), _>(2, |c| {
            if c.rank() == 0 {
                c.send(1, 11).unwrap();
                c.send_control(1, 22).unwrap();
                c.send_control(1, 33).unwrap();
            } else {
                for _ in 0..3 {
                    c.recv().unwrap();
                }
            }
        })
        .unwrap();
        let (sender, receiver) = (&res[0].1, &res[1].1);
        assert_eq!(sender.messages_sent, 1);
        assert_eq!(sender.control_sent, 2);
        // Receive-side split mirrors the send side — the asymmetry this
        // regression test exists for.
        assert_eq!(receiver.messages_received, 1);
        assert_eq!(receiver.control_received, 2);
    }

    #[test]
    fn bcast_control_received_as_control_everywhere() {
        let res = Cluster::run::<u64, (), _>(3, |c| {
            if c.rank() == 0 {
                c.bcast_control(|| 7).unwrap();
            } else {
                c.recv().unwrap();
            }
        })
        .unwrap();
        assert_eq!(res[0].1.control_sent, 2);
        for (_, m) in &res[1..] {
            assert_eq!(m.control_received, 1);
            assert_eq!(m.messages_received, 0);
        }
    }

    #[test]
    fn recv_guard_override_parsing() {
        assert_eq!(guard_from(None).unwrap(), RECV_DEADLOCK_GUARD);
        assert_eq!(guard_from(Some("120")).unwrap(), Duration::from_secs(120));
        assert_eq!(guard_from(Some(" 45 ")).unwrap(), Duration::from_secs(45));
        // Malformed overrides are *startup errors* (Error::Config), not
        // silent fallbacks — a mistyped guard must not mask as the
        // 30-minute default on a production run.
        for bad in ["0", "ten", "", "-5", "1.5"] {
            match guard_from(Some(bad)) {
                Err(Error::Config(msg)) => {
                    assert!(msg.contains("TRICOUNT_RECV_GUARD_SECS"), "{msg}");
                    assert!(msg.contains(bad) || bad.is_empty(), "{msg}");
                }
                other => panic!("guard_from({bad:?}) = {other:?}, expected Config error"),
            }
        }
        // The cached process-wide value resolves to *some* positive guard.
        assert!(recv_guard() >= Duration::from_secs(1));
    }

    #[test]
    fn barrier_orders_phases() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let phase1 = Arc::new(AtomicUsize::new(0));
        let p1 = phase1.clone();
        Cluster::run::<u64, (), _>(4, move |c| {
            p1.fetch_add(1, Ordering::SeqCst);
            c.barrier().unwrap();
            // After the barrier every rank must observe all 4 increments.
            assert_eq!(p1.load(Ordering::SeqCst), 4);
        })
        .unwrap();
    }

    #[test]
    fn single_rank_cluster() {
        let res = Cluster::run::<u64, u64, _>(1, |c| c.reduce_sum(7).unwrap()).unwrap();
        assert_eq!(res[0].0, 7);
    }

    #[test]
    fn rank_panic_is_reported() {
        let r = Cluster::run::<u64, (), _>(2, |c| {
            if c.rank() == 1 {
                panic!("injected fault");
            }
        });
        match r {
            Err(Error::RankFailure { rank, ops, msg }) => {
                assert_eq!(rank, 1);
                assert_eq!(ops, 0, "a panicking rank has no metrics to report ops from");
                assert!(msg.contains("injected fault"), "{msg}");
            }
            other => panic!("expected rank-failure error, got {other:?}"),
        }
    }

    #[test]
    fn rank_error_propagates_without_poisoning() {
        // A rank returning Err must surface as that error — not a panic,
        // not a poisoned cluster. Rank 0 exits cleanly on its own.
        let r = Cluster::try_run::<u64, u64, _>(2, |c| {
            if c.rank() == 1 {
                Err(Error::Cluster("injected comm failure".into()))
            } else {
                Ok(7)
            }
        });
        match r {
            Err(Error::RankFailure { rank, msg, .. }) => {
                assert_eq!(rank, 1);
                assert!(msg.contains("injected comm failure"), "{msg}");
            }
            other => panic!("expected the rank's error, got {other:?}"),
        }
    }

    #[test]
    fn lowest_failing_rank_wins() {
        let r = Cluster::try_run::<u64, (), _>(3, |c| {
            if c.rank() > 0 {
                Err(Error::Cluster(format!("rank {} failed", c.rank())))
            } else {
                Ok(())
            }
        });
        match r {
            Err(Error::RankFailure { rank, msg, .. }) => {
                assert_eq!(rank, 1, "rank-id tiebreak at equal op counts");
                assert!(msg.contains("rank 1"), "{msg}");
            }
            other => panic!("expected rank 1's error, got {other:?}"),
        }
    }

    #[test]
    fn lowest_op_count_failure_wins_over_lowest_rank() {
        // Root-cause attribution: rank 2 fails after *fewer* transport
        // ops than rank 1, so rank 2 is the reported failure even though
        // rank 1 has the lower id. (Rank 1 does 4 sends before failing;
        // rank 2 does 1. Rank 0 drains everything and succeeds.)
        let r = Cluster::try_run::<u64, (), _>(3, |c| match c.rank() {
            1 => {
                for i in 0..4 {
                    c.send(0, i).unwrap();
                }
                Err(Error::Cluster("late symptom".into()))
            }
            2 => {
                c.send(0, 99).unwrap();
                Err(Error::Cluster("early root cause".into()))
            }
            _ => {
                for _ in 0..5 {
                    c.recv().unwrap();
                }
                Ok(())
            }
        });
        match r {
            Err(Error::RankFailure { rank, ops, msg }) => {
                assert_eq!(rank, 2, "{msg}");
                assert_eq!(ops, 1);
                assert!(msg.contains("early root cause"), "{msg}");
            }
            other => panic!("expected rank 2's failure, got {other:?}"),
        }
    }

    // The end-to-end check that a malformed TRICOUNT_RECV_GUARD_SECS fails
    // `Cluster::try_run` at startup lives in `tests/recv_guard_env.rs` —
    // it mutates the process environment, which would race the other
    // cluster tests in this binary.

    #[test]
    fn spans_recorded_on_channel_fabric() {
        use crate::obs::span::ClockDomain;
        let res = Cluster::run::<u64, (), _>(2, |c| {
            c.span_begin(SpanPhase::Compute);
            if c.rank() == 0 {
                c.send(1, 5).unwrap();
            } else {
                c.recv().unwrap();
            }
            c.span_end();
            c.barrier().unwrap();
            c.reduce_sum(1).unwrap();
        })
        .unwrap();
        for (rank, (_, m)) in res.iter().enumerate() {
            let count =
                |p: SpanPhase| m.spans.spans.iter().filter(|s| s.phase == p).count();
            assert_eq!(m.spans.domain, ClockDomain::Wall);
            assert_eq!(count(SpanPhase::Compute), 1, "rank {rank}");
            assert_eq!(count(SpanPhase::Barrier), 1, "rank {rank}");
            assert_eq!(count(SpanPhase::Reduce), 1, "rank {rank}");
            assert_eq!(count(SpanPhase::Send), usize::from(rank == 0));
            assert_eq!(count(SpanPhase::RecvWait), usize::from(rank == 1));
            assert_eq!(m.spans.dropped, 0);
        }
    }

    #[test]
    fn self_send_delivered() {
        Cluster::run::<u64, (), _>(2, |c| {
            let me = c.rank();
            c.send(me, 99).unwrap();
            let (src, v) = c.recv().unwrap();
            assert_eq!((src, v), (me, 99));
        })
        .unwrap();
    }
}
