//! Threaded message-passing backend — the crate's "MPI".
//!
//! Each of the `P` ranks runs on its own OS thread with private state;
//! ranks communicate **only** through typed point-to-point channels plus a
//! barrier, mirroring the paper's distributed-memory model (§II Computation
//! Model). No rank reads another rank's partition; the dynamic-LB algorithm
//! shares the graph read-only via `Arc`, which is faithful to §V's
//! assumption that every machine stores the whole network.
//!
//! The API is deliberately MPI-shaped: `send`, `try_recv`, `recv_timeout`,
//! `barrier`, `reduce_sum` — so the algorithm modules read like the paper's
//! pseudocode.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Barrier, Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::comm::metrics::CommMetrics;
use crate::error::{Error, Result};

/// Default guard against protocol deadlocks in tests/CI. Override with the
/// `TRICOUNT_RECV_GUARD_SECS` env var (whole seconds, > 0) for large-graph
/// CI and local stress runs that legitimately block longer than 30s.
pub const RECV_DEADLOCK_GUARD: Duration = Duration::from_secs(30);

/// The effective guard: `TRICOUNT_RECV_GUARD_SECS` if set and valid, else
/// [`RECV_DEADLOCK_GUARD`]. Read once and cached for the process.
pub fn recv_guard() -> Duration {
    static GUARD: OnceLock<Duration> = OnceLock::new();
    *GUARD.get_or_init(|| {
        guard_from(std::env::var("TRICOUNT_RECV_GUARD_SECS").ok().as_deref())
    })
}

/// Parse an override value; invalid / missing / zero falls back to the
/// default (factored out of [`recv_guard`] so the policy is testable
/// without racing on process-global env state).
fn guard_from(val: Option<&str>) -> Duration {
    match val.and_then(|s| s.trim().parse::<u64>().ok()) {
        Some(secs) if secs > 0 => Duration::from_secs(secs),
        _ => RECV_DEADLOCK_GUARD,
    }
}

/// Internal channel envelope: sender rank, control-plane flag, payload.
/// The flag lets the receive side account control traffic apart from data
/// (the send side already does), keeping [`CommMetrics`] symmetric.
struct Envelope<M> {
    src: usize,
    control: bool,
    msg: M,
}

/// Messages must declare their wire size so the metrics layer can account
/// bytes the way the paper reasons about them (neighbor-list words).
pub trait Payload: Send + 'static {
    /// Serialized size in bytes if this were on an MPI wire.
    fn size_bytes(&self) -> u64;
}

struct Shared {
    barrier: Barrier,
    reduce_cells: Mutex<Vec<u64>>,
    reduce_acc: AtomicU64,
}

/// A rank's endpoint: its id, channels to every peer, and its metrics.
pub struct Comm<M: Payload> {
    rank: usize,
    size: usize,
    senders: Vec<Sender<Envelope<M>>>,
    receiver: Receiver<Envelope<M>>,
    shared: Arc<Shared>,
    /// Per-rank counters, returned to the driver by [`Cluster::run`].
    pub metrics: CommMetrics,
}

impl<M: Payload> Comm<M> {
    /// This rank's id in `0..size`.
    #[inline]
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Number of ranks `P`.
    #[inline]
    pub fn size(&self) -> usize {
        self.size
    }

    /// Point-to-point send (asynchronous, unbounded buffering — MPI eager
    /// mode). Sending to self is allowed (delivered through the queue).
    pub fn send(&mut self, dst: usize, msg: M) -> Result<()> {
        self.metrics.messages_sent += 1;
        self.metrics.bytes_sent += msg.size_bytes();
        self.senders[dst]
            .send(Envelope { src: self.rank, control: false, msg })
            .map_err(|_| Error::Cluster(format!("rank {} send to dead rank {dst}", self.rank)))
    }

    /// Control-plane send (completion notifiers, task protocol): accounted
    /// separately from data messages, on both endpoints.
    pub fn send_control(&mut self, dst: usize, msg: M) -> Result<()> {
        self.metrics.control_sent += 1;
        self.senders[dst]
            .send(Envelope { src: self.rank, control: true, msg })
            .map_err(|_| Error::Cluster(format!("rank {} send to dead rank {dst}", self.rank)))
    }

    /// Broadcast a control message to every other rank via `clone_fn`.
    pub fn bcast_control(&mut self, make: impl Fn() -> M) -> Result<()> {
        for dst in 0..self.size {
            if dst != self.rank {
                self.send_control(dst, make())?;
            }
        }
        Ok(())
    }

    /// Account one delivered envelope and unwrap it.
    #[inline]
    fn accept(&mut self, env: Envelope<M>) -> (usize, M) {
        if env.control {
            self.metrics.control_received += 1;
        } else {
            self.metrics.messages_received += 1;
        }
        (env.src, env.msg)
    }

    /// Non-blocking receive.
    pub fn try_recv(&mut self) -> Option<(usize, M)> {
        match self.receiver.try_recv() {
            Ok(env) => Some(self.accept(env)),
            Err(_) => None,
        }
    }

    /// Blocking receive with the deadlock guard; records wait time as idle.
    pub fn recv(&mut self) -> Result<(usize, M)> {
        let guard = recv_guard();
        let start = Instant::now();
        let r = self.receiver.recv_timeout(guard);
        self.metrics.recv_wait += start.elapsed();
        match r {
            Ok(env) => Ok(self.accept(env)),
            Err(RecvTimeoutError::Timeout) => Err(Error::Cluster(format!(
                "rank {} recv timed out after {guard:?} (protocol deadlock?)",
                self.rank
            ))),
            Err(RecvTimeoutError::Disconnected) => {
                Err(Error::Cluster(format!("rank {} peers disconnected", self.rank)))
            }
        }
    }

    /// Synchronize all ranks (MPI_Barrier).
    pub fn barrier(&self) {
        self.shared.barrier.wait();
    }

    /// Sum-reduce a u64 across all ranks; everyone receives the total
    /// (MPI_Allreduce(SUM)). Internally: write cell → barrier → read.
    pub fn reduce_sum(&self, value: u64) -> u64 {
        {
            let mut cells = self.shared.reduce_cells.lock().unwrap();
            cells[self.rank] = value;
        }
        self.shared.barrier.wait();
        if self.rank == 0 {
            let cells = self.shared.reduce_cells.lock().unwrap();
            let sum = cells.iter().sum();
            self.shared.reduce_acc.store(sum, Ordering::SeqCst);
        }
        self.shared.barrier.wait();
        self.shared.reduce_acc.load(Ordering::SeqCst)
    }
}

/// The cluster launcher: spawns `P` rank threads and runs `f` on each.
pub struct Cluster;

impl Cluster {
    /// Run `f(rank_comm)` on `p` ranks; returns each rank's result and its
    /// metrics, indexed by rank. Propagates rank panics as [`Error::Cluster`].
    pub fn run<M, R, F>(p: usize, f: F) -> Result<Vec<(R, CommMetrics)>>
    where
        M: Payload,
        R: Send,
        F: Fn(&mut Comm<M>) -> R + Sync,
    {
        Self::try_run(p, |c| Ok(f(c)))
    }

    /// [`Cluster::run`] for fallible rank programs: a rank returning `Err`
    /// surfaces as that [`Error`] from the whole run (lowest rank wins when
    /// several fail) instead of poisoning the cluster with a panic. All
    /// ranks are still joined before returning; a peer blocked on a rank
    /// that bailed out is bounded by the [`recv_guard`] timeout and then
    /// fails with its own `Err`.
    pub fn try_run<M, R, F>(p: usize, f: F) -> Result<Vec<(R, CommMetrics)>>
    where
        M: Payload,
        R: Send,
        F: Fn(&mut Comm<M>) -> Result<R> + Sync,
    {
        assert!(p >= 1, "cluster needs at least one rank");
        let mut senders = Vec::with_capacity(p);
        let mut receivers = Vec::with_capacity(p);
        for _ in 0..p {
            let (tx, rx) = std::sync::mpsc::channel();
            senders.push(tx);
            receivers.push(rx);
        }
        let shared = Arc::new(Shared {
            barrier: Barrier::new(p),
            reduce_cells: Mutex::new(vec![0; p]),
            reduce_acc: AtomicU64::new(0),
        });

        let mut comms: Vec<Comm<M>> = receivers
            .into_iter()
            .enumerate()
            .map(|(rank, receiver)| Comm {
                rank,
                size: p,
                senders: senders.clone(),
                receiver,
                shared: shared.clone(),
                metrics: CommMetrics::default(),
            })
            .collect();
        drop(senders);

        let f = &f;
        let results: Vec<std::thread::Result<(Result<R>, CommMetrics)>> =
            std::thread::scope(|s| {
                let handles: Vec<_> = comms
                    .drain(..)
                    .map(|mut comm| {
                        s.spawn(move || {
                            let start = Instant::now();
                            let r = f(&mut comm);
                            comm.metrics.total = start.elapsed();
                            (r, comm.metrics)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join()).collect()
            });

        let mut out = Vec::with_capacity(p);
        for (rank, r) in results.into_iter().enumerate() {
            match r {
                Ok((Ok(x), m)) => out.push((x, m)),
                Ok((Err(e), _)) => return Err(e),
                Err(e) => {
                    let msg = e
                        .downcast_ref::<String>()
                        .cloned()
                        .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                        .unwrap_or_else(|| "unknown panic".into());
                    return Err(Error::Cluster(format!("rank {rank} panicked: {msg}")));
                }
            }
        }
        Ok(out)
    }
}

impl Payload for Vec<u32> {
    fn size_bytes(&self) -> u64 {
        (self.len() * 4) as u64
    }
}

impl Payload for u64 {
    fn size_bytes(&self) -> u64 {
        8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_pass() {
        // Each rank sends its rank² to the next; sums must match.
        let res = Cluster::run::<u64, u64, _>(4, |c| {
            let next = (c.rank() + 1) % c.size();
            c.send(next, (c.rank() * c.rank()) as u64).unwrap();
            let (_src, v) = c.recv().unwrap();
            v
        })
        .unwrap();
        let mut got: Vec<u64> = res.iter().map(|(v, _)| *v).collect();
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 4, 9]);
    }

    #[test]
    fn reduce_sum_all_ranks_see_total() {
        let res = Cluster::run::<u64, u64, _>(5, |c| c.reduce_sum(c.rank() as u64 + 1)).unwrap();
        for (v, _) in res {
            assert_eq!(v, 15);
        }
    }

    #[test]
    fn metrics_count_messages_and_bytes() {
        let res = Cluster::run::<Vec<u32>, (), _>(2, |c| {
            if c.rank() == 0 {
                c.send(1, vec![1, 2, 3]).unwrap();
            } else {
                let (src, msg) = c.recv().unwrap();
                assert_eq!(src, 0);
                assert_eq!(msg, vec![1, 2, 3]);
            }
        })
        .unwrap();
        assert_eq!(res[0].1.messages_sent, 1);
        assert_eq!(res[0].1.bytes_sent, 12);
        assert_eq!(res[1].1.messages_received, 1);
    }

    #[test]
    fn control_receives_accounted_apart_from_data() {
        let res = Cluster::run::<u64, (), _>(2, |c| {
            if c.rank() == 0 {
                c.send(1, 11).unwrap();
                c.send_control(1, 22).unwrap();
                c.send_control(1, 33).unwrap();
            } else {
                for _ in 0..3 {
                    c.recv().unwrap();
                }
            }
        })
        .unwrap();
        let (sender, receiver) = (&res[0].1, &res[1].1);
        assert_eq!(sender.messages_sent, 1);
        assert_eq!(sender.control_sent, 2);
        // Receive-side split mirrors the send side — the asymmetry this
        // regression test exists for.
        assert_eq!(receiver.messages_received, 1);
        assert_eq!(receiver.control_received, 2);
    }

    #[test]
    fn bcast_control_received_as_control_everywhere() {
        let res = Cluster::run::<u64, (), _>(3, |c| {
            if c.rank() == 0 {
                c.bcast_control(|| 7).unwrap();
            } else {
                c.recv().unwrap();
            }
        })
        .unwrap();
        assert_eq!(res[0].1.control_sent, 2);
        for (_, m) in &res[1..] {
            assert_eq!(m.control_received, 1);
            assert_eq!(m.messages_received, 0);
        }
    }

    #[test]
    fn recv_guard_override_parsing() {
        assert_eq!(guard_from(None), RECV_DEADLOCK_GUARD);
        assert_eq!(guard_from(Some("120")), Duration::from_secs(120));
        assert_eq!(guard_from(Some(" 45 ")), Duration::from_secs(45));
        assert_eq!(guard_from(Some("0")), RECV_DEADLOCK_GUARD, "zero is invalid");
        assert_eq!(guard_from(Some("ten")), RECV_DEADLOCK_GUARD);
        assert_eq!(guard_from(Some("")), RECV_DEADLOCK_GUARD);
        // The cached process-wide value resolves to *some* positive guard.
        assert!(recv_guard() >= Duration::from_secs(1));
    }

    #[test]
    fn barrier_orders_phases() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let phase1 = Arc::new(AtomicUsize::new(0));
        let p1 = phase1.clone();
        Cluster::run::<u64, (), _>(4, move |c| {
            p1.fetch_add(1, Ordering::SeqCst);
            c.barrier();
            // After the barrier every rank must observe all 4 increments.
            assert_eq!(p1.load(Ordering::SeqCst), 4);
        })
        .unwrap();
    }

    #[test]
    fn single_rank_cluster() {
        let res = Cluster::run::<u64, u64, _>(1, |c| c.reduce_sum(7)).unwrap();
        assert_eq!(res[0].0, 7);
    }

    #[test]
    fn rank_panic_is_reported() {
        let r = Cluster::run::<u64, (), _>(2, |c| {
            if c.rank() == 1 {
                panic!("injected fault");
            }
        });
        match r {
            Err(Error::Cluster(msg)) => assert!(msg.contains("injected fault"), "{msg}"),
            other => panic!("expected cluster error, got {other:?}"),
        }
    }

    #[test]
    fn rank_error_propagates_without_poisoning() {
        // A rank returning Err must surface as that error — not a panic,
        // not a poisoned cluster. Rank 0 exits cleanly on its own.
        let r = Cluster::try_run::<u64, u64, _>(2, |c| {
            if c.rank() == 1 {
                Err(Error::Cluster("injected comm failure".into()))
            } else {
                Ok(7)
            }
        });
        match r {
            Err(Error::Cluster(msg)) => assert!(msg.contains("injected comm failure"), "{msg}"),
            other => panic!("expected the rank's error, got {other:?}"),
        }
    }

    #[test]
    fn lowest_failing_rank_wins() {
        let r = Cluster::try_run::<u64, (), _>(3, |c| {
            if c.rank() > 0 {
                Err(Error::Cluster(format!("rank {} failed", c.rank())))
            } else {
                Ok(())
            }
        });
        match r {
            Err(Error::Cluster(msg)) => assert!(msg.contains("rank 1"), "{msg}"),
            other => panic!("expected rank 1's error, got {other:?}"),
        }
    }

    #[test]
    fn self_send_delivered() {
        Cluster::run::<u64, (), _>(2, |c| {
            let me = c.rank();
            c.send(me, 99).unwrap();
            let (src, v) = c.recv().unwrap();
            assert_eq!((src, v), (me, 99));
        })
        .unwrap();
    }
}
