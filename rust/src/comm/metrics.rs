//! Per-rank communication and timing metrics.
//!
//! The paper's evaluation reasons about *message redundancy* (direct vs
//! surrogate, §IV-C), *communication overhead* (weak scaling, Figs 9/15)
//! and *idle time* (Fig 13). Every backend records these uniformly so the
//! experiment drivers can print them alongside runtime.

use std::time::Duration;

use crate::adj::stats::KernelStats;
use crate::comm::transport::{Wire, WireReader};
use crate::obs::span::SpanLog;

/// Counters a single rank accumulates during a run.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CommMetrics {
    /// Point-to-point data messages sent.
    pub messages_sent: u64,
    /// Payload bytes sent (sum of declared message sizes).
    pub bytes_sent: u64,
    /// Data messages received and processed.
    pub messages_received: u64,
    /// Broadcast/control messages sent (completion notifiers, task protocol).
    pub control_sent: u64,
    /// Control messages received — accounted apart from data so per-rank
    /// message totals stay symmetric with the send side (Σ messages_sent =
    /// Σ messages_received and Σ control_sent = Σ control_received once a
    /// run drains).
    pub control_received: u64,
    /// Wall time spent blocked waiting to receive (the measured component of
    /// idle time on the threads backend).
    pub recv_wait: Duration,
    /// Transport ops this rank issued (sends + receives + collective
    /// arrivals) — the clock the fault plans' `Kill::at_op` counts in, and
    /// the key the cluster launcher orders failures by (lowest op count =
    /// root cause).
    pub transport_ops: u64,
    /// Coalesced frames sent (`comm::coalesce`): wire envelopes that each
    /// carry `≥ 1` logical records. A frame is *also* counted once in
    /// `messages_sent` (it is one envelope); the aggregation ratio is
    /// `coalesced_sent / frames_sent`.
    pub frames_sent: u64,
    /// Coalesced frames received and unpacked.
    pub frames_received: u64,
    /// Logical records packed into outgoing frames. The conformance suite
    /// asserts Σ `coalesced_sent` == Σ `coalesced_received` cluster-wide —
    /// the frame-content analogue of the envelope symmetry above.
    pub coalesced_sent: u64,
    /// Logical records unpacked from incoming frames.
    pub coalesced_received: u64,
    /// Row-broadcast records sent (`algo::tile2d` phase 1): the tag-class
    /// split of `coalesced_sent` the 2D driver's audit needs — Σ sent ==
    /// Σ received per class, checked by the conformance suite.
    pub row_bcast_sent: u64,
    /// Row-broadcast records received.
    pub row_bcast_received: u64,
    /// Column-broadcast records sent (`algo::tile2d` phase 2).
    pub col_bcast_sent: u64,
    /// Column-broadcast records received.
    pub col_bcast_received: u64,
    /// Request retransmissions after a `recv_deadline` expiry (ft/ bounded
    /// retry). 0 on a fault-free run — the conformance drop cells assert
    /// these are bounded and non-zero where a message was eaten.
    pub retries: u64,
    /// Socket-fabric framing bytes (`comm::tcp`): per-frame headers,
    /// handshakes, collective/retire/result frames, and any delta between
    /// a payload's encoded length and its declared `size_bytes`. Purely
    /// **additive** on top of `bytes_sent` — the declared-payload counters
    /// are identical across fabrics (the byte-accounting equivalence the
    /// conformance suite pins), and this field is 0 everywhere except the
    /// TCP backend. Sent-side accounting only.
    pub wire_overhead_bytes: u64,
    /// Work units re-executed on recovery attempts (`ft::supervisor`):
    /// the measured cost of surviving the fault, reported apart from
    /// `work_units` so the fault-free cost stays comparable.
    pub reexec_work_units: u64,
    /// Payload bytes re-sent on recovery attempts.
    pub reexec_bytes: u64,
    /// Wall time of the rank's whole run.
    pub total: Duration,
    /// Work units executed, in the element steps the hybrid dispatch
    /// actually ran (merge/gallop per [`crate::intersect::adaptive_cost`],
    /// bitmap probe, or word-AND — [`crate::adj::intersect_cost`]); filled
    /// by the algorithms, used for load-imbalance reporting. The paper's
    /// merge-model measure Σ(d̂_v + d̂_u) lives on as the estimators in
    /// [`crate::partition::cost`].
    pub work_units: u64,
    /// **Measured** resident bytes of this rank's owned partition
    /// ([`crate::partition::owned::OwnedPartition::resident_bytes`]:
    /// offsets + targets + overlap row table). 0 for drivers that hold the
    /// whole graph instead of a partition (dynamic-LB, streaming).
    pub partition_bytes: u64,
    /// The scheme's arithmetic *prediction* for the same quantity
    /// ([`crate::partition::nonoverlap::PartitionSize::bytes`] /
    /// [`crate::partition::overlap::OverlapSize::bytes`]). `tricount
    /// count` and the CI smoke step gate on exact per-rank equality with
    /// [`CommMetrics::partition_bytes`].
    pub partition_bytes_pred: u64,
    /// Hub-bitmap accelerator bytes riding on the partition — budgeted
    /// opt-in state, reported apart from the CSR bytes the §IV
    /// space-efficiency claim is about.
    pub accel_bytes: u64,
    /// Kernel-path mix of the intersections *this rank* dispatched
    /// (`adj::stats` per-rank scoping — the launcher installs a per-rank
    /// sink for the rank program's duration). The process-global
    /// `adj::stats::snapshot()` remains the cross-rank sum.
    pub kernel: KernelStats,
    /// This rank's phase-span timeline (`obs::span`): wall-µs ticks on
    /// the channel fabric, virtual ticks on the testkit fabric. Replayed
    /// virtual schedules reproduce this log bit-identically.
    pub spans: SpanLog,
}

impl CommMetrics {
    /// Merge another rank's counters (for cluster-wide totals). Span
    /// logs are deliberately *not* concatenated — a timeline belongs to
    /// one rank; cluster totals keep an empty log.
    pub fn merge(&mut self, other: &CommMetrics) {
        self.messages_sent += other.messages_sent;
        self.bytes_sent += other.bytes_sent;
        self.messages_received += other.messages_received;
        self.control_sent += other.control_sent;
        self.control_received += other.control_received;
        self.recv_wait += other.recv_wait;
        self.transport_ops += other.transport_ops;
        self.frames_sent += other.frames_sent;
        self.frames_received += other.frames_received;
        self.coalesced_sent += other.coalesced_sent;
        self.coalesced_received += other.coalesced_received;
        self.row_bcast_sent += other.row_bcast_sent;
        self.row_bcast_received += other.row_bcast_received;
        self.col_bcast_sent += other.col_bcast_sent;
        self.col_bcast_received += other.col_bcast_received;
        self.retries += other.retries;
        self.wire_overhead_bytes += other.wire_overhead_bytes;
        self.reexec_work_units += other.reexec_work_units;
        self.reexec_bytes += other.reexec_bytes;
        self.total = self.total.max(other.total);
        self.work_units += other.work_units;
        self.partition_bytes += other.partition_bytes;
        self.partition_bytes_pred += other.partition_bytes_pred;
        self.accel_bytes += other.accel_bytes;
        self.kernel.merge(&other.kernel);
    }
}

/// Per-rank metrics cross the socket fabric in the result gather
/// (`comm::tcp::run_tcp_hooked`), span timeline included, so rank 0 can
/// merge remote snapshots exactly as the in-process launcher does.
/// Field order is declaration order; durations travel as microseconds.
impl Wire for CommMetrics {
    fn write_to(&self, out: &mut Vec<u8>) {
        self.messages_sent.write_to(out);
        self.bytes_sent.write_to(out);
        self.messages_received.write_to(out);
        self.control_sent.write_to(out);
        self.control_received.write_to(out);
        self.recv_wait.write_to(out);
        self.transport_ops.write_to(out);
        self.frames_sent.write_to(out);
        self.frames_received.write_to(out);
        self.coalesced_sent.write_to(out);
        self.coalesced_received.write_to(out);
        self.row_bcast_sent.write_to(out);
        self.row_bcast_received.write_to(out);
        self.col_bcast_sent.write_to(out);
        self.col_bcast_received.write_to(out);
        self.retries.write_to(out);
        self.wire_overhead_bytes.write_to(out);
        self.reexec_work_units.write_to(out);
        self.reexec_bytes.write_to(out);
        self.total.write_to(out);
        self.work_units.write_to(out);
        self.partition_bytes.write_to(out);
        self.partition_bytes_pred.write_to(out);
        self.accel_bytes.write_to(out);
        self.kernel.write_to(out);
        self.spans.write_to(out);
    }
    fn read_from(r: &mut WireReader<'_>) -> crate::error::Result<Self> {
        Ok(CommMetrics {
            messages_sent: u64::read_from(r)?,
            bytes_sent: u64::read_from(r)?,
            messages_received: u64::read_from(r)?,
            control_sent: u64::read_from(r)?,
            control_received: u64::read_from(r)?,
            recv_wait: Duration::read_from(r)?,
            transport_ops: u64::read_from(r)?,
            frames_sent: u64::read_from(r)?,
            frames_received: u64::read_from(r)?,
            coalesced_sent: u64::read_from(r)?,
            coalesced_received: u64::read_from(r)?,
            row_bcast_sent: u64::read_from(r)?,
            row_bcast_received: u64::read_from(r)?,
            col_bcast_sent: u64::read_from(r)?,
            col_bcast_received: u64::read_from(r)?,
            retries: u64::read_from(r)?,
            wire_overhead_bytes: u64::read_from(r)?,
            reexec_work_units: u64::read_from(r)?,
            reexec_bytes: u64::read_from(r)?,
            total: Duration::read_from(r)?,
            work_units: u64::read_from(r)?,
            partition_bytes: u64::read_from(r)?,
            partition_bytes_pred: u64::read_from(r)?,
            accel_bytes: u64::read_from(r)?,
            kernel: KernelStats::read_from(r)?,
            spans: SpanLog::read_from(r)?,
        })
    }
}

/// Cluster-wide summary over per-rank metrics.
#[derive(Clone, Debug, Default)]
pub struct ClusterMetrics {
    pub per_rank: Vec<CommMetrics>,
}

impl ClusterMetrics {
    pub fn totals(&self) -> CommMetrics {
        let mut t = CommMetrics::default();
        for m in &self.per_rank {
            t.merge(m);
        }
        t
    }

    /// Largest measured per-rank partition residency — the quantity the
    /// paper's Table II / Fig 7 bound (a cluster is sized by its most
    /// loaded rank).
    pub fn max_partition_bytes(&self) -> u64 {
        self.per_rank.iter().map(|m| m.partition_bytes).max().unwrap_or(0)
    }

    /// Largest predicted per-rank partition size.
    pub fn max_partition_bytes_pred(&self) -> u64 {
        self.per_rank.iter().map(|m| m.partition_bytes_pred).max().unwrap_or(0)
    }

    /// Largest per-rank hub-accelerator residency.
    pub fn max_accel_bytes(&self) -> u64 {
        self.per_rank.iter().map(|m| m.accel_bytes).max().unwrap_or(0)
    }

    /// `Some(rank)` of the first rank whose measured partition bytes
    /// diverge from the prediction; `None` when the accounting is exact
    /// everywhere (the invariant `tricount count` gates on).
    pub fn partition_accounting_divergence(&self) -> Option<usize> {
        self.per_rank
            .iter()
            .position(|m| m.partition_bytes != m.partition_bytes_pred)
    }

    /// Logical records per wire frame (`coalesced_sent / frames_sent`) —
    /// the aggregation win of `comm::coalesce`. 1.0 when nothing was
    /// coalesced (no frames sent).
    pub fn aggregation_ratio(&self) -> f64 {
        let t = self.totals();
        if t.frames_sent == 0 {
            1.0
        } else {
            t.coalesced_sent as f64 / t.frames_sent as f64
        }
    }

    /// Load imbalance: max work / mean work (1.0 = perfectly balanced).
    pub fn imbalance(&self) -> f64 {
        if self.per_rank.is_empty() {
            return 1.0;
        }
        let works: Vec<u64> = self.per_rank.iter().map(|m| m.work_units).collect();
        let max = *works.iter().max().unwrap() as f64;
        let mean = works.iter().sum::<u64>() as f64 / works.len() as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_accumulates() {
        let mut a = CommMetrics { messages_sent: 2, bytes_sent: 10, ..Default::default() };
        let b = CommMetrics {
            messages_sent: 3,
            bytes_sent: 5,
            work_units: 7,
            control_received: 4,
            partition_bytes: 100,
            partition_bytes_pred: 100,
            accel_bytes: 16,
            frames_sent: 2,
            coalesced_sent: 9,
            row_bcast_sent: 5,
            col_bcast_received: 3,
            wire_overhead_bytes: 40,
            kernel: KernelStats { list_list: 3, list_bitmap: 1, bitmap_bitmap: 2, simd_blocked: 0 },
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.messages_sent, 5);
        assert_eq!(a.bytes_sent, 15);
        assert_eq!(a.work_units, 7);
        assert_eq!(a.control_received, 4);
        assert_eq!(a.frames_sent, 2);
        assert_eq!(a.coalesced_sent, 9);
        assert_eq!(a.row_bcast_sent, 5);
        assert_eq!(a.col_bcast_received, 3);
        assert_eq!(a.partition_bytes, 100);
        assert_eq!(a.partition_bytes_pred, 100);
        assert_eq!(a.accel_bytes, 16);
        assert_eq!(a.wire_overhead_bytes, 40);
        // Kernel mixes sum field-wise; span logs stay per-rank (empty here).
        assert_eq!(a.kernel.total(), 6);
        assert_eq!(a.spans.recorded(), 0);
    }

    #[test]
    fn metrics_wire_roundtrip_is_exact() {
        use crate::obs::span::{ClockDomain, Span, SpanLog, SpanPhase};
        let m = CommMetrics {
            messages_sent: 3,
            bytes_sent: 99,
            control_sent: 2,
            recv_wait: Duration::from_micros(1234),
            transport_ops: 17,
            retries: 1,
            wire_overhead_bytes: 60,
            total: Duration::from_micros(5678),
            kernel: KernelStats { list_list: 4, list_bitmap: 2, bitmap_bitmap: 1, simd_blocked: 3 },
            spans: SpanLog {
                domain: ClockDomain::Wall,
                spans: vec![Span { phase: SpanPhase::Compute, t_start: 1, t_end: 9 }],
                dropped: 0,
            },
            ..Default::default()
        };
        let back = CommMetrics::from_bytes(&m.to_bytes()).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn partition_accounting_helpers() {
        let mut cm = ClusterMetrics {
            per_rank: vec![
                CommMetrics { partition_bytes: 40, partition_bytes_pred: 40, accel_bytes: 8, ..Default::default() },
                CommMetrics { partition_bytes: 72, partition_bytes_pred: 72, ..Default::default() },
            ],
        };
        assert_eq!(cm.max_partition_bytes(), 72);
        assert_eq!(cm.max_partition_bytes_pred(), 72);
        assert_eq!(cm.max_accel_bytes(), 8);
        assert_eq!(cm.partition_accounting_divergence(), None);
        cm.per_rank[1].partition_bytes = 68;
        assert_eq!(cm.partition_accounting_divergence(), Some(1));
        assert_eq!(ClusterMetrics::default().max_partition_bytes(), 0);
    }

    #[test]
    fn imbalance_computation() {
        let cm = ClusterMetrics {
            per_rank: vec![
                CommMetrics { work_units: 10, ..Default::default() },
                CommMetrics { work_units: 30, ..Default::default() },
            ],
        };
        assert!((cm.imbalance() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn aggregation_ratio_totals() {
        assert_eq!(ClusterMetrics::default().aggregation_ratio(), 1.0);
        let cm = ClusterMetrics {
            per_rank: vec![
                CommMetrics { frames_sent: 2, coalesced_sent: 10, ..Default::default() },
                CommMetrics { frames_sent: 2, coalesced_sent: 6, ..Default::default() },
            ],
        };
        assert!((cm.aggregation_ratio() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn imbalance_empty_and_zero() {
        assert_eq!(ClusterMetrics::default().imbalance(), 1.0);
        let cm = ClusterMetrics { per_rank: vec![CommMetrics::default(); 3] };
        assert_eq!(cm.imbalance(), 1.0);
    }
}
