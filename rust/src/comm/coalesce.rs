//! Per-destination message aggregation (after Sanders & Uhl,
//! arXiv 2302.11443): many small logical messages to the same peer are
//! packed into bounded **frames**, so the per-message constant α is paid
//! once per frame instead of once per envelope. The 2D tile driver
//! (`algo::tile2d`) broadcasts its row/column pieces this way, and the
//! direct scheme (`algo::direct`) batches its per-edge request/reply
//! traffic through the same buffer — [`crate::comm::metrics::CommMetrics`]
//! counts frames and logical items separately so the aggregation ratio is
//! auditable (`coalesced_sent / frames_sent`).
//!
//! ## Frame format
//!
//! A frame's payload is a flat `Vec<u32>` of back-to-back records:
//!
//! ```text
//! [tag, len, payload_0, …, payload_{len-1}]  [tag, len, …]  …
//! ```
//!
//! `tag` is protocol-defined (a vertex id for the tile broadcasts, a
//! request/response discriminant for the direct scheme); `len` is the
//! payload word count. Packing order is push order, so identical pushes
//! produce byte-identical frames — replay determinism needs nothing more.
//!
//! ## Flush watermark
//!
//! A buffer closes its current frame as soon as the payload reaches the
//! watermark (in words): frames are bounded by `watermark + 2 + largest
//! record`, and a single record larger than the watermark travels alone.
//! `flush()` drains whatever remains — senders call it at the end of a
//! sweep (and whenever a peer may be blocked waiting on the content).

use crate::comm::transport::{Wire, WireReader};

/// Default flush watermark: 1024 payload words = 4 KiB frames.
pub const DEFAULT_WATERMARK_WORDS: usize = 1024;

/// A packed frame: `items` logical records in `words`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Frame {
    /// Number of logical records packed into this frame.
    pub items: u64,
    /// Back-to-back `[tag, len, payload…]` records.
    pub words: Vec<u32>,
}

impl Wire for Frame {
    fn write_to(&self, out: &mut Vec<u8>) {
        self.items.write_to(out);
        self.words.write_to(out);
    }
    fn read_from(r: &mut WireReader<'_>) -> crate::error::Result<Self> {
        Ok(Frame { items: u64::read_from(r)?, words: Vec::<u32>::read_from(r)? })
    }
}

impl Frame {
    /// Wire size of the frame as a message payload (8-byte header + the
    /// packed words) — what [`crate::comm::threads::Payload::size_bytes`]
    /// reports for frame-carrying message variants.
    pub fn bytes(&self) -> u64 {
        8 + 4 * self.words.len() as u64
    }

    /// Iterate the `(tag, payload)` records of this frame.
    pub fn records(&self) -> Records<'_> {
        records(&self.words)
    }
}

/// Per-destination coalescing buffer. One per peer; see the module docs.
#[derive(Debug)]
pub struct CoalescingBuffer {
    watermark: usize,
    items: u64,
    words: Vec<u32>,
}

impl CoalescingBuffer {
    /// A buffer that closes frames at `watermark` payload words
    /// (`watermark ≥ 1`; use [`DEFAULT_WATERMARK_WORDS`] unless the
    /// protocol has a reason not to).
    pub fn new(watermark: usize) -> Self {
        assert!(watermark >= 1, "coalescing watermark must be positive");
        CoalescingBuffer { watermark, items: 0, words: Vec::new() }
    }

    /// Append one logical record. Returns the closed frame when the
    /// appended record brings the payload to (or past) the watermark —
    /// the caller sends it immediately, keeping frames bounded.
    #[must_use = "a returned frame must be sent, or its records are lost"]
    pub fn push(&mut self, tag: u32, payload: &[u32]) -> Option<Frame> {
        self.words.reserve(2 + payload.len());
        self.words.push(tag);
        self.words.push(payload.len() as u32);
        self.words.extend_from_slice(payload);
        self.items += 1;
        if self.words.len() >= self.watermark {
            self.flush()
        } else {
            None
        }
    }

    /// Drain the buffered records as a final (possibly short) frame;
    /// `None` when nothing is buffered.
    pub fn flush(&mut self) -> Option<Frame> {
        if self.items == 0 {
            return None;
        }
        let f = Frame { items: self.items, words: std::mem::take(&mut self.words) };
        self.items = 0;
        Some(f)
    }

    /// True iff no records are buffered.
    pub fn is_empty(&self) -> bool {
        self.items == 0
    }
}

/// Iterate `[tag, len, payload…]` records out of a packed word slice.
/// Frames are only built by [`CoalescingBuffer`], so malformation is a
/// protocol bug: a truncated trailing record stops iteration (and trips a
/// debug assertion) rather than panicking on the wire path.
pub fn records(words: &[u32]) -> Records<'_> {
    Records { words, at: 0 }
}

/// See [`records`]. Yields `(tag, payload)` per record.
pub struct Records<'a> {
    words: &'a [u32],
    at: usize,
}

impl<'a> Iterator for Records<'a> {
    type Item = (u32, &'a [u32]);

    fn next(&mut self) -> Option<(u32, &'a [u32])> {
        if self.at >= self.words.len() {
            return None;
        }
        if self.at + 2 > self.words.len() {
            debug_assert!(false, "truncated record header");
            return None;
        }
        let tag = self.words[self.at];
        let len = self.words[self.at + 1] as usize;
        let start = self.at + 2;
        if start + len > self.words.len() {
            debug_assert!(false, "truncated record payload");
            return None;
        }
        self.at = start + len;
        Some((tag, &self.words[start..start + len]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn watermark_closes_frames() {
        // Watermark 8: each record is 2 + 2 = 4 words, so every second
        // push closes a frame.
        let mut b = CoalescingBuffer::new(8);
        assert!(b.push(1, &[10, 11]).is_none());
        let f = b.push(2, &[20, 21]).expect("watermark reached");
        assert_eq!(f.items, 2);
        assert_eq!(f.words.len(), 8);
        assert!(b.is_empty());
        assert!(b.flush().is_none(), "flush after close is empty");
    }

    #[test]
    fn oversize_record_travels_alone() {
        let mut b = CoalescingBuffer::new(4);
        let big: Vec<u32> = (0..100).collect();
        let f = b.push(7, &big).expect("oversize record closes immediately");
        assert_eq!(f.items, 1);
        assert_eq!(f.words.len(), 102);
        assert!(b.is_empty());
    }

    #[test]
    fn frame_round_trip() {
        let mut b = CoalescingBuffer::new(1 << 20);
        let recs: Vec<(u32, Vec<u32>)> = (0..50)
            .map(|i| (i, (0..(i % 7) as u32).map(|x| x * 3 + i).collect()))
            .collect();
        for (tag, payload) in &recs {
            assert!(b.push(*tag, payload).is_none());
        }
        let f = b.flush().expect("non-empty");
        assert_eq!(f.items, recs.len() as u64);
        let got: Vec<(u32, Vec<u32>)> =
            f.records().map(|(t, p)| (t, p.to_vec())).collect();
        assert_eq!(got, recs);
        assert_eq!(f.bytes(), 8 + 4 * f.words.len() as u64);
    }

    #[test]
    fn packing_order_is_deterministic() {
        // Identical push sequences ⇒ byte-identical frame sequences.
        let run = || {
            let mut b = CoalescingBuffer::new(16);
            let mut frames = Vec::new();
            for i in 0..40u32 {
                let payload: Vec<u32> = (0..(i % 5)).collect();
                if let Some(f) = b.push(i, &payload) {
                    frames.push(f);
                }
            }
            frames.extend(b.flush());
            frames
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn items_conserved_across_frames() {
        let mut b = CoalescingBuffer::new(8);
        let mut frames = Vec::new();
        for i in 0..100u32 {
            if let Some(f) = b.push(i, &[i, i + 1, i + 2]) {
                frames.push(f);
            }
        }
        frames.extend(b.flush());
        let items: u64 = frames.iter().map(|f| f.items).sum();
        let records: usize = frames.iter().map(|f| f.records().count()).sum();
        assert_eq!(items, 100);
        assert_eq!(records, 100);
        // Every frame except possibly the last is at or just past the
        // watermark; none exceeds watermark + header + record.
        for f in &frames {
            assert!(f.words.len() <= 8 + 2 + 3, "bounded: {}", f.words.len());
        }
    }

    #[test]
    fn empty_payload_records() {
        let mut b = CoalescingBuffer::new(64);
        assert!(b.push(5, &[]).is_none());
        let f = b.flush().unwrap();
        let recs: Vec<_> = f.records().collect();
        assert_eq!(recs, vec![(5u32, &[][..])]);
    }
}
