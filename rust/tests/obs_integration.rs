//! obs/ end-to-end: span timelines harvested through real counting runs,
//! wall-clock conservation on the channel fabric, virtual-time replay
//! determinism on the testkit fabric, and the snapshot/trace exports
//! validating against their own schemas.

use std::sync::Arc;

use tricount::adj::HubThreshold;
use tricount::algo::surrogate;
use tricount::comm::metrics::ClusterMetrics;
use tricount::config::CostFn;
use tricount::gen::rng::Rng;
use tricount::graph::ordering::Oriented;
use tricount::obs::span::{ClockDomain, SpanPhase};
use tricount::obs::MetricsRegistry;
use tricount::partition::balance::balanced_ranges;
use tricount::partition::cost::{cost_vector, prefix_sums};
use tricount::testkit::sched::SimConfig;
use tricount::testkit::sim::Fabric;

fn workload() -> (Arc<Oriented>, Vec<std::ops::Range<u32>>) {
    let g = tricount::gen::pa::preferential_attachment(600, 8, &mut Rng::seeded(99));
    let o = Arc::new(Oriented::from_graph(&g));
    let ranges = balanced_ranges(&prefix_sums(&cost_vector(&o, CostFn::SurrogateNew)), 4);
    (o, ranges)
}

/// Σ blocked-phase span time per rank must fit inside the rank's measured
/// total. Each wall span truncates independently to whole µs, so every
/// recorded span can overshoot the truncated total by < 1 µs — hence the
/// `recorded + slack` allowance.
#[test]
fn wall_spans_conserve_time_on_channel_fabric() {
    let (o, ranges) = workload();
    let r = surrogate::run(&o, &ranges, HubThreshold::Auto).unwrap();
    for (rank, m) in r.metrics.per_rank.iter().enumerate() {
        assert_eq!(m.spans.domain, ClockDomain::Wall, "rank {rank}");
        assert!(m.spans.recorded() > 0, "rank {rank}: no spans");
        assert_eq!(m.spans.dropped, 0, "rank {rank}: ring overflowed");
        let blocked = m.spans.phase_ticks(SpanPhase::RecvWait)
            + m.spans.phase_ticks(SpanPhase::Barrier)
            + m.spans.phase_ticks(SpanPhase::Reduce);
        let budget = m.total.as_micros() as u64 + m.spans.recorded() as u64 + 2;
        assert!(
            blocked <= budget,
            "rank {rank}: blocked {blocked} µs exceeds total {budget} µs"
        );
        for s in &m.spans.spans {
            assert!(s.t_end >= s.t_start, "rank {rank}: inverted span {s:?}");
            assert!(s.t_end <= budget, "rank {rank}: span past run end {s:?}");
        }
    }
}

/// The obs/ clock contract on the testkit fabric: same seed ⇒ the exact
/// same virtual-time span timeline, not just the same trace hash.
#[test]
fn virtual_time_spans_replay_identically() {
    let (o, ranges) = workload();
    let run = |seed: u64| {
        let fabric = Fabric::Sim(SimConfig::adversarial(seed));
        surrogate::run_on(&fabric, &o, &ranges, HubThreshold::Auto).0.unwrap().metrics
    };
    let (a, b) = (run(3), run(3));
    for (rank, (ma, mb)) in a.per_rank.iter().zip(b.per_rank.iter()).enumerate() {
        assert_eq!(ma.spans.domain, ClockDomain::Virtual, "rank {rank}");
        assert_eq!(ma.spans, mb.spans, "rank {rank}: replay timeline differs");
        assert_eq!(ma.recv_wait, mb.recv_wait, "rank {rank}");
        assert_eq!(ma.total, mb.total, "rank {rank}");
    }
    // And a different schedule seed is allowed to (and here does) move time.
    let c = run(4);
    assert_eq!(a.per_rank.len(), c.per_rank.len());
}

/// Same-seed virtual runs export byte-identical Perfetto traces — the
/// property `tricount conformance --trace-out` leans on.
#[test]
fn virtual_trace_export_is_byte_identical() {
    let (o, ranges) = workload();
    let trace = |_| {
        let fabric = Fabric::Sim(SimConfig::adversarial(11));
        let m = surrogate::run_on(&fabric, &o, &ranges, HubThreshold::Auto).0.unwrap().metrics;
        tricount::obs::export::cluster_trace_json("test", &m)
    };
    assert_eq!(trace(0), trace(1));
}

/// End to end: real run → registry snapshot → schema validation → renderer,
/// and the same metrics through the Perfetto exporter → trace validation.
#[test]
fn snapshot_and_trace_validate_end_to_end() {
    let (o, ranges) = workload();
    let r = surrogate::run(&o, &ranges, HubThreshold::Auto).unwrap();

    let mut reg = MetricsRegistry::new("test-e2e");
    reg.record_cluster(&r.metrics);
    reg.record_global_kernels(tricount::adj::stats::snapshot());
    reg.record_phase("count", 0.25);
    reg.note("integration test");
    let json = reg.snapshot_json();
    let v = tricount::obs::registry::validate_snapshot(&json).expect("schema-valid snapshot");
    let rendered = tricount::obs::report::render_snapshot(&v).expect("renderable snapshot");
    assert!(rendered.contains("command=test-e2e"), "{rendered}");

    let trace = tricount::obs::export::cluster_trace_json("test-e2e", &r.metrics);
    let events = tricount::obs::export::validate_trace(&trace).expect("valid trace");
    // Metadata (process + one per rank) plus at least one span per rank.
    assert!(events > 1 + 2 * r.metrics.per_rank.len(), "only {events} events");

    // Σ per-rank kernel mix is carried into the snapshot's rank objects
    // (exact equality with the process-global counters is asserted in the
    // single-test `obs_kernel_scoping` binary, where nothing else bumps
    // the globals).
    let total: u64 = r.metrics.per_rank.iter().map(|m| m.kernel.total()).sum();
    assert!(total > 0, "surrogate dispatched no intersections?");
    let empty = ClusterMetrics::default();
    assert_eq!(empty.totals().kernel.total(), 0);
}
