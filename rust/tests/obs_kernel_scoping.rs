//! Per-rank kernel-counter scoping (`adj::stats` → `CommMetrics::kernel`):
//! the launcher installs a per-rank sink, `record()` double-bumps it and
//! the process-global counters, so the global snapshot stays the exact
//! cross-rank sum.
//!
//! This binary holds ONLY this test on purpose: the global counters are
//! process-wide, so the `global == Σ per-rank` equality is only sound when
//! nothing else in the process dispatches intersections concurrently. The
//! looser per-rank assertions live in `obs_integration.rs` alongside the
//! full pipelines.

use tricount::adj::stats::KernelStats;
use tricount::adj::{self, NeighborView};
use tricount::comm::threads::Cluster;

#[test]
fn global_kernel_snapshot_is_exact_sum_of_rank_scopes() {
    tricount::adj::stats::reset();
    let a: Vec<u32> = (0..64).collect();
    let b: Vec<u32> = (0..64).map(|x| 2 * x).collect();

    // Rank r dispatches (r + 1) * 10 list×list intersections. The lists
    // are balanced and ≥ SIMD_BLOCK_MIN long, so the dispatch takes the
    // SWAR blocked tier — which must scope per rank exactly like the
    // scalar paths.
    let res = Cluster::run::<u64, u64, _>(2, |c| {
        let mut t = 0u64;
        for _ in 0..(c.rank() + 1) * 10 {
            adj::intersect_count(NeighborView::sorted(&a), NeighborView::sorted(&b), &mut t);
        }
        t
    })
    .unwrap();
    let global = tricount::adj::stats::snapshot();

    // Per-rank scoping: each rank's CommMetrics carries exactly its own mix.
    assert_eq!(res[0].1.kernel, KernelStats { simd_blocked: 10, ..Default::default() });
    assert_eq!(res[1].1.kernel, KernelStats { simd_blocked: 20, ..Default::default() });

    // The process-global counters remain the cross-rank sum.
    let mut sum = KernelStats::default();
    for (_, m) in &res {
        sum.merge(&m.kernel);
    }
    assert_eq!(global, sum);
    assert_eq!(global.total(), 30);
}
