//! Oversubscription regression gate (`#[ignore]`d — CI runs it in release).
//!
//! The PR-6 baseline recorded `rmat:16:16` at `--build-threads 8` running
//! 0.70× *slower* than serial on a 2-core host: spawning more scoped
//! threads than cores pays spawn + contention cost with zero extra
//! parallelism. [`tricount::par::clamp_to_host`] clamps every thread
//! request to `available_parallelism`, so an oversubscribed request must
//! now cost no more than serial (plus timing noise).

use tricount::adj::HubThreshold;
use tricount::pipeline::{run, Options};

#[test]
#[ignore = "timing-sensitive: run with --release (CI does)"]
fn oversubscribed_thread_request_does_not_regress() {
    let opts = Options {
        workloads: vec!["pa:30000:16".into()],
        threads: vec![1, 8],
        reps: 3,
        seed: 42,
        hub_threshold: HubThreshold::Auto,
    };
    let r = run(&opts).expect("pipeline run");
    let mut t1 = None;
    let mut t8 = None;
    for i in 0..r.rows.len() {
        match r.int(i, "threads").expect("threads column") {
            1 => t1 = Some(r.secs(i, "total_s").expect("total_s column")),
            8 => t8 = Some(r.secs(i, "total_s").expect("total_s column")),
            _ => {}
        }
    }
    let (t1, t8) = (t1.expect("T=1 row"), t8.expect("T=8 row"));
    assert!(
        t8 <= t1 * 1.1,
        "T=8 total {t8:.4}s > 1.1x T=1 total {t1:.4}s — the host clamp regressed"
    );
}
