//! Overhead gate for the obs/ instrumentation: the per-rank kernel sink
//! (a thread-local `Arc` bump riding on every `adj::record`) must cost the
//! intersection hot path < 3% — the acceptance budget the CI release run
//! enforces. `#[ignore]`d by default: it is a timing assertion and only
//! meaningful in release mode on a quiet machine
//! (`cargo test --release --test obs_overhead -- --ignored`).

use std::sync::Arc;
use std::time::Instant;

use tricount::adj::stats::{self, RankKernelCounters};
use tricount::adj::{self, NeighborView};
use tricount::gen::rng::Rng;

fn sorted_list(rng: &mut Rng, len: usize, universe: u32) -> Vec<u32> {
    let mut v: Vec<u32> = (0..len).map(|_| rng.next_u32() % universe).collect();
    v.sort_unstable();
    v.dedup();
    v
}

/// Min-of-samples timing of the dispatching intersection loop. Min (not
/// median) because scheduler noise only ever adds time; the minimum is the
/// best estimate of the true cost.
fn min_secs<F: FnMut() -> u64>(samples: usize, mut f: F) -> f64 {
    let mut sink = f(); // warmup
    let mut best = f64::INFINITY;
    for _ in 0..samples {
        let t0 = Instant::now();
        sink = sink.wrapping_add(f());
        best = best.min(t0.elapsed().as_secs_f64());
    }
    std::hint::black_box(sink);
    best
}

#[test]
#[ignore = "timing gate; run in release via CI (obs overhead step)"]
fn span_and_rank_counter_overhead_under_3_percent() {
    let mut rng = Rng::seeded(42);
    let a = sorted_list(&mut rng, 10_000, 1_000_000);
    let b = sorted_list(&mut rng, 10_000, 1_000_000);
    let body = || {
        let mut t = 0u64;
        for _ in 0..200 {
            adj::intersect_count(NeighborView::sorted(&a), NeighborView::sorted(&b), &mut t);
        }
        t
    };

    // Baseline: global counters only (no per-rank sink installed).
    let without = min_secs(9, body);

    // With the obs/ per-rank sink installed, exactly as the launcher does.
    let sink = Arc::new(RankKernelCounters::default());
    let scope = stats::install_rank(sink.clone());
    let with = min_secs(9, body);
    drop(scope);

    assert!(sink.snapshot().total() >= 200 * 9, "sink saw no bumps — scoping broken?");
    assert!(
        with <= without * 1.03,
        "per-rank kernel sink costs {:.2}% on the intersection hot path (budget 3%): \
         {with:.6}s with vs {without:.6}s without",
        (with / without - 1.0) * 100.0
    );
}
