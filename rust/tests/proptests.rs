//! Property tests over coordinator invariants: partitioning (routing),
//! task splitting (batching), message-elimination and termination (state) —
//! using the in-crate `prop` harness (proptest is unavailable offline; see
//! DESIGN.md §3).

use std::io::Write as _;
use std::sync::Arc;

use tricount::adj::HubThreshold;
use tricount::algo::tasks;
use tricount::config::CostFn;
use tricount::gen::rng::Rng;
use tricount::graph::ordering::Oriented;
use tricount::partition::balance::{balanced_ranges, owner_table, OwnerTable};
use tricount::partition::cost::{cost_vector, prefix_sums};
use tricount::partition::nonoverlap::partition_sizes;
use tricount::partition::overlap::overlap_sizes;
use tricount::partition::owned;
use tricount::prop::{arb_graph, arb_update_batches, quickcheck};
use tricount::seq::{naive, node_iterator};
use tricount::stream::compact::CompactionPolicy;
use tricount::stream::state::StreamState;
use tricount::stream::{parallel, window};

#[test]
fn prop_ranges_partition_v() {
    quickcheck("balanced ranges tile V", |rng, _| {
        let g = arb_graph(rng, 80);
        let o = Oriented::from_graph(&g);
        let f = match rng.below(4) {
            0 => CostFn::Unit,
            1 => CostFn::Degree,
            2 => CostFn::PatricBest,
            _ => CostFn::SurrogateNew,
        };
        let p = 1 + rng.below_usize(12);
        let ranges = balanced_ranges(&prefix_sums(&cost_vector(&o, f)), p);
        if ranges.len() != p {
            return Err(format!("expected {p} ranges, got {}", ranges.len()));
        }
        let mut at = 0u32;
        for r in &ranges {
            if r.start != at {
                return Err(format!("gap at {at}: {ranges:?}"));
            }
            at = r.end;
        }
        if at as usize != g.num_nodes() {
            return Err(format!("ranges end at {at}, n = {}", g.num_nodes()));
        }
        Ok(())
    });
}

#[test]
fn prop_owner_table_consistent_with_ranges() {
    quickcheck("owner table routing", |rng, _| {
        let g = arb_graph(rng, 60);
        let o = Oriented::from_graph(&g);
        let p = 1 + rng.below_usize(8);
        let ranges = balanced_ranges(&prefix_sums(&cost_vector(&o, CostFn::Degree)), p);
        let owner = owner_table(&ranges, g.num_nodes());
        let compact = OwnerTable::new(&ranges);
        for v in 0..g.num_nodes() as u32 {
            let i = owner[v as usize] as usize;
            if !ranges[i].contains(&v) {
                return Err(format!("node {v} routed to rank {i} ({:?})", ranges[i]));
            }
            // The O(P) bounds table must route identically to the O(n) one.
            if compact.owner_of(v) as usize != i {
                return Err(format!("OwnerTable routes {v} to {}, dense to {i}", compact.owner_of(v)));
            }
        }
        // Owner runs tile every oriented list with correctly-owned runs.
        for v in 0..g.num_nodes() as u32 {
            let nv = o.nbrs(v);
            let mut at = 0usize;
            for (j, run) in compact.runs(nv) {
                if run.start != at || run.is_empty() {
                    return Err(format!("runs of N_{v} do not tile: {run:?} at {at}"));
                }
                at = run.end;
                if nv[run].iter().any(|&u| owner[u as usize] != j) {
                    return Err(format!("run of N_{v} misrouted to {j}"));
                }
            }
            if at != nv.len() {
                return Err(format!("runs of N_{v} stop at {at}/{}", nv.len()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_nonoverlap_edges_tile_e() {
    quickcheck("non-overlapping partitions tile E", |rng, _| {
        let g = arb_graph(rng, 70);
        let o = Oriented::from_graph(&g);
        let p = 1 + rng.below_usize(10);
        let ranges = balanced_ranges(&prefix_sums(&cost_vector(&o, CostFn::SurrogateNew)), p);
        let sizes = partition_sizes(&o, &ranges);
        let total: u64 = sizes.iter().map(|s| s.edges).sum();
        if total != o.num_edges() {
            return Err(format!("edges {total} != m {}", o.num_edges()));
        }
        Ok(())
    });
}

#[test]
fn prop_overlap_dominates_nonoverlap_per_range() {
    quickcheck("overlap ⊇ non-overlap", |rng, _| {
        let g = arb_graph(rng, 70);
        let o = Oriented::from_graph(&g);
        let p = 1 + rng.below_usize(6);
        let ranges = balanced_ranges(&prefix_sums(&cost_vector(&o, CostFn::Degree)), p);
        let non = partition_sizes(&o, &ranges);
        let over = overlap_sizes(&g, &o, &ranges);
        for (i, (a, b)) in non.iter().zip(&over).enumerate() {
            if b.edges < a.edges || b.all_nodes < a.all_nodes {
                return Err(format!("partition {i}: overlap {b:?} < non {a:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_owned_partitions_measure_exactly_what_predictions_say() {
    // The PR 4 invariant: materialized per-rank storage equals the
    // arithmetic size accounting byte-for-byte, for both layouts, and the
    // §IV drivers report the same numbers through their metrics.
    quickcheck("owned resident bytes == predicted bytes", |rng, case| {
        let g = arb_graph(rng, 70);
        let o = Oriented::from_graph(&g);
        let p = 1 + rng.below_usize(8);
        let ranges = balanced_ranges(&prefix_sums(&cost_vector(&o, CostFn::SurrogateNew)), p);
        let parts = owned::extract_nonoverlapping(&o, &ranges, HubThreshold::Auto);
        for (i, (part, s)) in parts.iter().zip(partition_sizes(&o, &ranges)).enumerate() {
            if part.resident_bytes() != s.bytes() {
                return Err(format!(
                    "case {case} partition {i}: measured {} != predicted {}",
                    part.resident_bytes(),
                    s.bytes()
                ));
            }
        }
        let over = owned::extract_overlapping(&g, &o, &ranges, HubThreshold::Auto);
        for (i, (part, s)) in over.iter().zip(overlap_sizes(&g, &o, &ranges)).enumerate() {
            if part.resident_bytes() != s.bytes() {
                return Err(format!(
                    "case {case} overlap partition {i}: measured {} != predicted {}",
                    part.resident_bytes(),
                    s.bytes()
                ));
            }
        }
        // End-to-end: the drivers' metrics carry the same exact accounting.
        let r = tricount::algo::surrogate::run(&o, &ranges, HubThreshold::Auto)
            .map_err(|e| e.to_string())?;
        if r.metrics.partition_accounting_divergence().is_some() {
            return Err(format!("case {case}: surrogate metrics diverged"));
        }
        Ok(())
    });
}

#[test]
fn prop_task_queue_covers_and_shrinks() {
    quickcheck("shrinking task queue invariants", |rng, _| {
        let n = 1 + rng.below_usize(300);
        let costs: Vec<u64> = (0..n).map(|_| rng.below(50)).collect();
        let prefix = prefix_sums(&costs);
        let workers = 1 + rng.below_usize(10);
        let tp = tasks::half_point(&prefix);
        let initial = tasks::equal_cost_tasks(&prefix, 0, tp, workers);
        let queue = tasks::shrinking_tasks(&prefix, tp, workers);
        // Initial + queue together tile [0, n).
        let mut all = initial.clone();
        all.extend(&queue);
        if !tasks::tiles(&all, 0, n) {
            return Err(format!("initial+queue don't tile [0,{n}): {all:?}"));
        }
        // Eqn 2 invariant: each task's cost is within one atomic node of its
        // shrinking target `remaining/(P−1)` — i.e. granularity follows the
        // geometric schedule, with single indivisible nodes the only excess.
        let total = prefix[n];
        let cost = |t: &tasks::Task| prefix[t.end() as usize] - prefix[t.start as usize];
        let max_node = |t: &tasks::Task| {
            (t.start..t.end())
                .map(|v| costs[v as usize])
                .max()
                .unwrap_or(0)
        };
        let mut remaining = total - prefix[tp];
        for t in &queue {
            let target = remaining / workers as u64;
            let c = cost(t);
            if c > target + max_node(t) {
                return Err(format!(
                    "task {t:?} cost {c} exceeds target {target} + atomic slack"
                ));
            }
            remaining -= c;
        }
        if remaining != 0 {
            return Err(format!("queue left {remaining} cost unassigned"));
        }
        Ok(())
    });
}

#[test]
fn prop_surrogate_message_elimination() {
    // LastProc invariant: data messages ≤ Σ_v (distinct remote partitions
    // in N_v) — i.e. never a redundant send — and the count is *exactly*
    // that (the scheme sends once per (v, remote partition)).
    quickcheck("surrogate sends once per (v, partition)", |rng, _| {
        let g = arb_graph(rng, 60);
        let o = Arc::new(Oriented::from_graph(&g));
        let p = 1 + rng.below_usize(6);
        let ranges = balanced_ranges(&prefix_sums(&cost_vector(&o, CostFn::Degree)), p);
        let owner = owner_table(&ranges, g.num_nodes());
        let r = tricount::algo::surrogate::run(&o, &ranges, HubThreshold::Auto)
            .map_err(|e| e.to_string())?;
        let mut expect = 0u64;
        for v in 0..g.num_nodes() as u32 {
            let mine = owner[v as usize];
            let mut parts: Vec<u32> = o
                .nbrs(v)
                .iter()
                .map(|&u| owner[u as usize])
                .filter(|&j| j != mine)
                .collect();
            parts.dedup(); // neighbors sorted by id ⇒ partitions consecutive
            expect += parts.len() as u64;
        }
        let got: u64 = r.metrics.per_rank.iter().map(|m| m.messages_sent).sum();
        if got != expect {
            return Err(format!("messages {got} != expected {expect}"));
        }
        Ok(())
    });
}

#[test]
fn prop_all_parallel_algorithms_match_oracle() {
    quickcheck("parallel == naive oracle", |rng, i| {
        let g = arb_graph(rng, 40);
        let expect = naive::edge_iterator_count(&g);
        let o = Arc::new(Oriented::from_graph(&g));
        if node_iterator::count(&o) != expect {
            return Err("sequential != oracle".into());
        }
        let p = 1 + rng.below_usize(5);
        let ranges = balanced_ranges(&prefix_sums(&cost_vector(&o, CostFn::SurrogateNew)), p);
        let s = tricount::algo::surrogate::run(&o, &ranges, HubThreshold::Auto)
            .map_err(|e| e.to_string())?
            .triangles;
        if s != expect {
            return Err(format!("case {i}: surrogate {s} != {expect}"));
        }
        // Alternate direct/dynamic to keep runtime bounded.
        if i % 2 == 0 {
            let d = tricount::algo::direct::run(&o, &ranges, HubThreshold::Auto)
                .map_err(|e| e.to_string())?
                .triangles;
            if d != expect {
                return Err(format!("case {i}: direct {d} != {expect}"));
            }
        } else {
            let d = tricount::algo::dynamic_lb::run(&o, 2 + rng.below_usize(4), Default::default())
                .map_err(|e| e.to_string())?
                .triangles;
            if d != expect {
                return Err(format!("case {i}: dynamic {d} != {expect}"));
            }
        }
        Ok(())
    });
}

/// A random base graph drawn per-case from one of the three generator
/// families the paper evaluates: PA, R-MAT and Erdős–Rényi.
fn arb_stream_base(rng: &mut Rng, case: u32) -> tricount::graph::csr::Csr {
    match case % 3 {
        0 => {
            let n = 10 + rng.below_usize(60);
            tricount::gen::pa::preferential_attachment(n, 4, rng)
        }
        1 => tricount::gen::rmat::rmat(5 + rng.below(2) as u32, 4, Default::default(), rng),
        _ => {
            let n = 8 + rng.below_usize(50);
            let m = rng.below_usize(2 * n + 1);
            tricount::gen::erdos_renyi::gnm(n, m, rng)
        }
    }
}

#[test]
fn prop_stream_matches_rebuild_across_generators() {
    // After ANY random insert/delete batch sequence, the incremental count
    // equals a from-scratch Fig-1 recount of the rebuilt graph — with
    // aggressive compaction in half the cases to exercise the fold.
    quickcheck("stream == from-scratch rebuild (PA/R-MAT/ER)", |rng, case| {
        let g = arb_stream_base(rng, case);
        let batches = arb_update_batches(rng, g.num_nodes(), 6, 30);
        let policy = if case % 2 == 0 {
            CompactionPolicy { every_batches: 2, overlay_ratio: 0.0 }
        } else {
            CompactionPolicy::never()
        };
        let mut s = StreamState::with_policy(g, policy);
        for b in &batches {
            s.apply_batch(b).map_err(|e| e.to_string())?;
        }
        let rebuilt = s.recount().map_err(|e| e.to_string())?;
        if s.triangles() != rebuilt {
            return Err(format!(
                "case {case}: incremental {} != rebuilt {rebuilt} after {} batches",
                s.triangles(),
                batches.len()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_parallel_stream_agrees_with_sequential_at_any_p() {
    quickcheck("parallel stream == sequential stream", |rng, case| {
        let g = arb_stream_base(rng, case);
        let batches = arb_update_batches(rng, g.num_nodes(), 4, 20);
        let mut s = StreamState::with_policy(g.clone(), CompactionPolicy::default());
        for b in &batches {
            s.apply_batch(b).map_err(|e| e.to_string())?;
        }
        let p = 1 + rng.below_usize(6);
        let r = parallel::run(&g, &batches, p, parallel::StreamOptions::default())
            .map_err(|e| e.to_string())?;
        if r.final_triangles != s.triangles() {
            return Err(format!(
                "case {case}: P={p} parallel {} != sequential {}",
                r.final_triangles,
                s.triangles()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_windowed_stream_matches_rebuild() {
    // The sliding window exercises the deletion path hard: every batch
    // past W carries expiries. Exactness must survive.
    quickcheck("windowed stream == rebuild", |rng, case| {
        let g = arb_stream_base(rng, case);
        let batches = arb_update_batches(rng, g.num_nodes(), 6, 15);
        let w = 1 + rng.below_usize(3);
        let mut sw = window::SlidingWindow::new(g, w, CompactionPolicy::default());
        let mut last = sw.state().triangles();
        for b in &batches {
            last = sw.push(b).map_err(|e| e.to_string())?.triangles;
        }
        let rebuilt = sw.state().recount().map_err(|e| e.to_string())?;
        if last != rebuilt {
            return Err(format!("case {case}: W={w} windowed {last} != rebuilt {rebuilt}"));
        }
        Ok(())
    });
}

#[test]
fn prop_hybrid_counts_equal_pure_sorted_across_drivers() {
    // The adj/ hub-bitmap layer is an accelerator, never a semantic change:
    // for every generator family (PA / R-MAT / ER via `arb_stream_base`'s
    // distribution) and every threshold — including the 0, 1 and `off` edge
    // cases, which force all-bitmap and no-bitmap extremes — the seq,
    // dynamic-LB and surrogate drivers must produce the pure-sorted count.
    use tricount::adj::HubThreshold;
    quickcheck("hybrid == sorted for all drivers/thresholds", |rng, case| {
        let g = arb_stream_base(rng, case);
        let pure = Oriented::from_graph_with(&g, HubThreshold::Off);
        let expect = node_iterator::count(&pure);
        for t in [
            HubThreshold::Fixed(0),
            HubThreshold::Fixed(1),
            HubThreshold::Fixed(1 + rng.below_usize(8)),
            HubThreshold::Auto,
            HubThreshold::Off,
        ] {
            let o = Arc::new(Oriented::from_graph_with(&g, t));
            o.validate(&g).map_err(|e| format!("{t}: {e}"))?;
            let s = node_iterator::count(&o);
            if s != expect {
                return Err(format!("case {case} {t}: seq {s} != {expect}"));
            }
            // Rotate the parallel drivers to keep runtime bounded
            // (rng-drawn, so driver choice decorrelates from the
            // case-keyed generator family).
            let got = match rng.below(3) {
                0 => {
                    let p = 1 + rng.below_usize(4);
                    let ranges =
                        balanced_ranges(&prefix_sums(&cost_vector(&o, CostFn::Hybrid)), p);
                    // Partitions inherit the tested hub policy directly.
                    tricount::algo::surrogate::run(&o, &ranges, t)
                        .map_err(|e| e.to_string())?
                        .triangles
                }
                1 => {
                    tricount::algo::dynamic_lb::run(&o, 2 + rng.below_usize(3), Default::default())
                        .map_err(|e| e.to_string())?
                        .triangles
                }
                _ => {
                    let p = 1 + rng.below_usize(4);
                    let ranges =
                        balanced_ranges(&prefix_sums(&cost_vector(&o, CostFn::Degree)), p);
                    tricount::algo::patric::run(&g, &o, &ranges, t)
                        .map_err(|e| e.to_string())?
                        .triangles
                }
            };
            if got != expect {
                return Err(format!("case {case} {t}: parallel {got} != {expect}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_stream_hub_bitmaps_preserve_exactness() {
    // The streaming Δ counter's per-batch hub-bitmap cache must never
    // change a count. A star base makes node 0 a true hub (degree ≫ the
    // 2×-average auto cutoff) and the batches are biased to touch it, so
    // the cache's probe/word-AND paths actually execute.
    use tricount::stream::batch::{Batch, EdgeUpdate};
    quickcheck("stream hub cache == rebuild", |rng, case| {
        let n = 80 + rng.below_usize(60);
        let g = tricount::graph::classic::star(n - 1);
        let batches: Vec<Batch> = (0..4)
            .map(|_| {
                Batch::new(
                    (0..20)
                        .map(|_| {
                            // Half the ops pin an endpoint on the hub.
                            let u = if rng.chance(0.5) { 0 } else { rng.below(n as u64) as u32 };
                            let v = rng.below(n as u64) as u32;
                            if rng.chance(0.3) {
                                EdgeUpdate::delete(u, v)
                            } else {
                                EdgeUpdate::insert(u, v)
                            }
                        })
                        .collect(),
                )
            })
            .collect();
        let mut s = StreamState::with_policy(g.clone(), CompactionPolicy::never());
        for b in &batches {
            s.apply_batch(b).map_err(|e| e.to_string())?;
        }
        let rebuilt = s.recount().map_err(|e| e.to_string())?;
        if s.triangles() != rebuilt {
            return Err(format!(
                "case {case}: incremental {} != rebuilt {rebuilt}",
                s.triangles()
            ));
        }
        let p = 1 + rng.below_usize(4);
        let r = parallel::run(&g, &batches, p, parallel::StreamOptions::default())
            .map_err(|e| e.to_string())?;
        if r.final_triangles != rebuilt {
            return Err(format!("case {case}: P={p} {} != {rebuilt}", r.final_triangles));
        }
        Ok(())
    });
}

#[test]
fn prop_orientation_preserves_triangle_structure() {
    quickcheck("orientation invariants", |rng, _| {
        let g = arb_graph(rng, 60);
        let o = Oriented::from_graph(&g);
        o.validate(&g).map_err(|e| e)?;
        // Σ d̂_v = m and each d̂ bounded by degree.
        let sum: u64 = (0..g.num_nodes() as u32).map(|v| o.effective_degree(v) as u64).sum();
        if sum != g.num_edges() {
            return Err(format!("Σd̂ = {sum} != m = {}", g.num_edges()));
        }
        Ok(())
    });
}

/// A random base graph from any of the four generator families (PA, R-MAT,
/// Erdős–Rényi, geometric contact) — the build-determinism satellite's
/// required coverage.
fn arb_build_base(rng: &mut Rng, case: u32) -> tricount::graph::csr::Csr {
    match case % 4 {
        0 => {
            let n = 20 + rng.below_usize(400);
            tricount::gen::pa::preferential_attachment(n, 6, rng)
        }
        1 => tricount::gen::rmat::rmat(6 + rng.below(3) as u32, 6, Default::default(), rng),
        2 => {
            let n = 16 + rng.below_usize(300);
            let m = rng.below_usize(4 * n + 1);
            tricount::gen::erdos_renyi::gnm(n, m, rng)
        }
        _ => {
            let n = 64 + rng.below_usize(300);
            tricount::gen::geometric::miami_like(n, 8, rng)
        }
    }
}

#[test]
fn prop_parallel_build_bit_identical_across_generators() {
    // The tentpole's determinism guarantee: at build-threads 1/2/8 the
    // radix builder emits bit-identical offsets/targets to the seed's
    // comparison-sort builder — across PA/R-MAT/ER/geometric inputs
    // salted with duplicates, reversed orientations and self loops.
    quickcheck("parallel radix build == serial sort build", |rng, case| {
        // Every eighth case is big enough (m ≫ MIN_EDGES_PER_THREAD) that
        // T=8 really runs eight scatter chunks instead of clamping serial.
        let g = if case % 8 == 0 {
            tricount::gen::pa::preferential_attachment(20_000, 8, rng)
        } else {
            arb_build_base(rng, case)
        };
        let n = g.num_nodes();
        let mut edges: Vec<(u32, u32)> = g.edges().collect();
        let extra = rng.below_usize(edges.len().min(40) + 1);
        for _ in 0..extra {
            let &(u, v) = &edges[rng.below_usize(edges.len())];
            edges.push((v, u)); // duplicate, reversed
        }
        edges.push((0, 0)); // self loop
        let reference = tricount::graph::builder::from_edge_list_sort_baseline(n, edges.clone())
            .map_err(|e| e.to_string())?;
        for t in [1usize, 2, 8] {
            let built = tricount::graph::builder::from_edge_list_threads(n, edges.clone(), t)
                .map_err(|e| e.to_string())?;
            if built != reference {
                return Err(format!("case {case}: radix build diverged at T={t} (n={n})"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_parallel_orientation_identical_and_hub_stats_stable() {
    // Orientation + hub index built at T=1/2/8 must agree bit-for-bit
    // (offsets/targets/degrees) and report identical hub-row stats.
    quickcheck("parallel orientation == serial", |rng, case| {
        let g = arb_build_base(rng, case);
        let policy = match case % 3 {
            0 => tricount::adj::HubThreshold::Auto,
            1 => tricount::adj::HubThreshold::Off,
            _ => tricount::adj::HubThreshold::Fixed(1 + rng.below_usize(8)),
        };
        let serial = Oriented::from_graph_threads(&g, policy, 1);
        for t in [2usize, 8] {
            let par = Oriented::from_graph_threads(&g, policy, t);
            if par.offsets() != serial.offsets()
                || par.targets() != serial.targets()
                || par.degrees() != serial.degrees()
            {
                return Err(format!("case {case}: orientation diverged at T={t}"));
            }
            if par.hub_stats() != serial.hub_stats() {
                return Err(format!("case {case}: hub stats diverged at T={t}"));
            }
        }
        serial.validate(&g).map_err(|e| format!("case {case}: {e}"))
    });
}

#[test]
fn prop_stream_compaction_equivalent_through_parallel_builder() {
    // stream::compact calls graph::builder per batch; with the process
    // default raised to 8 build threads the maintained count and the final
    // compacted graph must be unchanged (the builder is bit-identical at
    // any thread count).
    quickcheck("stream compaction via parallel builder == serial", |rng, case| {
        // Every fourth case uses a base big enough to clear the builder's
        // MIN_EDGES_PER_THREAD floor, so compaction really runs multi-chunk;
        // the rest cover the tiny edge cases (which clamp back to serial).
        let g = if case % 4 == 0 {
            tricount::gen::pa::preferential_attachment(5_000, 8, rng)
        } else {
            arb_stream_base(rng, case)
        };
        let batches = arb_update_batches(rng, g.num_nodes(), 4, 25);
        let policy = CompactionPolicy { every_batches: 1, overlay_ratio: 0.0 };
        let run_with = |threads: usize| -> Result<StreamState, String> {
            let prev = tricount::par::default_threads();
            tricount::par::set_default_threads(threads);
            let mut s = StreamState::with_policy(g.clone(), policy);
            let mut result = Ok(());
            for b in &batches {
                if let Err(e) = s.apply_batch(b) {
                    result = Err(e.to_string());
                    break;
                }
            }
            tricount::par::set_default_threads(prev);
            result.map(|_| s)
        };
        let serial = run_with(1)?;
        let par = run_with(8)?;
        if par.triangles() != serial.triangles() {
            return Err(format!(
                "case {case}: count {} != {} through 8-thread compaction",
                par.triangles(),
                serial.triangles()
            ));
        }
        let gs = serial.snapshot().map_err(|e| e.to_string())?;
        let gp = par.snapshot().map_err(|e| e.to_string())?;
        if gs != gp {
            return Err(format!("case {case}: compacted graphs diverged"));
        }
        Ok(())
    });
}

#[test]
fn prop_tcg_write_load_is_identity_and_corruption_is_detected() {
    // The `.tcg` ingestion satellite: write→load is the identity for any
    // generated graph; flipping the magic, version or integrity footer is a
    // Config error; truncating at a random byte is an error, never a panic.
    quickcheck("tcg round-trip + corruption taxonomy", |rng, case| {
        let g = arb_build_base(rng, case);
        let path = std::env::temp_dir().join(format!(
            "tricount_prop_{}_{case}.tcg",
            std::process::id()
        ));
        tricount::graph::io::write_tcg(&g, &path).map_err(|e| e.to_string())?;
        let back = tricount::graph::io::read_tcg(&path).map_err(|e| e.to_string())?;
        if back != g {
            return Err(format!("case {case}: .tcg reload != written graph"));
        }
        let bytes = std::fs::read(&path).map_err(|e| e.to_string())?;
        // Single-byte corruptions: magic (offset 0), version (offset 8),
        // footer (last byte) — each must surface as a Config error.
        for (name, off) in [("magic", 0), ("version", 8), ("footer", bytes.len() - 1)] {
            let mut bad = bytes.clone();
            bad[off] ^= 0xFF;
            std::fs::write(&path, &bad).map_err(|e| e.to_string())?;
            match tricount::graph::io::read_tcg(&path) {
                Err(tricount::error::Error::Config(_)) => {}
                other => {
                    return Err(format!(
                        "case {case}: corrupted {name} gave {other:?}, want Config"
                    ))
                }
            }
        }
        // Truncation at any cut point: an error (the file always ends with
        // an 8-byte footer, so a strict prefix can never verify), no panic.
        let cut = rng.below_usize(bytes.len());
        std::fs::write(&path, &bytes[..cut]).map_err(|e| e.to_string())?;
        if tricount::graph::io::read_tcg(&path).is_ok() {
            return Err(format!("case {case}: truncation at {cut} loaded"));
        }
        std::fs::remove_file(&path).map_err(|e| e.to_string())?;
        Ok(())
    });
}

#[test]
fn prop_chunk_parallel_parse_matches_serial() {
    // The chunk-parallel text parse must be bit-identical to serial at any
    // thread count (DESIGN.md §8 extended to parsing), including documents
    // salted with comments and blank lines — and a malformed line must be
    // reported with the same (global) line number no matter how the
    // document was chunked.
    quickcheck("chunked text parse == serial (PA/R-MAT/ER)", |rng, case| {
        // Every fourth case is big enough (≫ the 4 KiB chunk floor) that
        // T=8 really scans eight chunks instead of clamping to serial.
        let g = if case % 4 == 0 {
            tricount::gen::pa::preferential_attachment(20_000, 8, rng)
        } else {
            arb_stream_base(rng, case)
        };
        let mut text: Vec<u8> = Vec::new();
        for (u, v) in g.edges() {
            if rng.chance(0.03) {
                text.extend_from_slice(b"# interleaved comment\n");
            }
            if rng.chance(0.03) {
                text.push(b'\n');
            }
            writeln!(text, "{u} {v}").map_err(|e| e.to_string())?;
        }
        let serial =
            tricount::graph::io::parse_edge_list_bytes(&text, 1).map_err(|e| e.to_string())?;
        for t in [2usize, 8] {
            let par =
                tricount::graph::io::parse_edge_list_bytes(&text, t).map_err(|e| e.to_string())?;
            if par != serial {
                return Err(format!("case {case}: chunked parse diverged at T={t}"));
            }
        }
        // Error equivalence: same first-error line at every thread count.
        text.extend_from_slice(b"bogus tokens here\n");
        let want = tricount::graph::io::parse_edge_list_bytes(&text, 1)
            .err()
            .ok_or_else(|| format!("case {case}: serial parse accepted bad line"))?
            .to_string();
        for t in [2usize, 8] {
            let got = tricount::graph::io::parse_edge_list_bytes(&text, t)
                .err()
                .ok_or_else(|| format!("case {case}: T={t} parse accepted bad line"))?
                .to_string();
            if got != want {
                return Err(format!(
                    "case {case}: T={t} error `{got}` != serial `{want}`"
                ));
            }
        }
        Ok(())
    });
}
