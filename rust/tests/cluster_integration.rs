//! Cross-algorithm integration: every parallel algorithm, the sequential
//! kernel, the naive oracles, and the hybrid counter must agree exactly on
//! a spread of workloads and processor counts — the repo's strongest
//! end-to-end correctness signal.

use std::sync::Arc;

use tricount::adj::HubThreshold;
use tricount::algo::{direct, dynamic_lb, patric, surrogate};
use tricount::config::CostFn;
use tricount::gen::rng::Rng;
use tricount::graph::csr::Csr;
use tricount::graph::ordering::Oriented;
use tricount::graph::{classic, io};
use tricount::partition::balance::balanced_ranges;
use tricount::partition::cost::{cost_vector, prefix_sums};
use tricount::seq::{naive, node_iterator};
use tricount::tensor::hybrid;

/// Run every counter on the graph and assert exact agreement. The §IV
/// drivers run on fully materialized owned partitions; every run is also
/// checked for exact measured == predicted partition residency.
fn assert_all_agree(g: &Csr, expect: u64, ps: &[usize]) {
    let o = Arc::new(Oriented::from_graph(g));
    assert_eq!(node_iterator::count(&o), expect, "sequential");
    assert_eq!(naive::edge_iterator_count(g), expect, "edge iterator");
    assert_eq!(hybrid::count_reference(&o, g.num_nodes() / 3).triangles, expect, "hybrid");

    for &p in ps {
        let prefix = prefix_sums(&cost_vector(&o, CostFn::SurrogateNew));
        let ranges = balanced_ranges(&prefix, p);
        let s = surrogate::run(&o, &ranges, HubThreshold::Auto).unwrap();
        assert_eq!(s.triangles, expect, "surrogate P={p}");
        assert_eq!(s.metrics.partition_accounting_divergence(), None, "surrogate mem P={p}");
        let d = direct::run(&o, &ranges, HubThreshold::Auto).unwrap();
        assert_eq!(d.triangles, expect, "direct P={p}");
        assert_eq!(d.metrics.partition_accounting_divergence(), None, "direct mem P={p}");

        let patric_prefix = prefix_sums(&cost_vector(&o, CostFn::PatricBest));
        let patric_ranges = balanced_ranges(&patric_prefix, p);
        let pr = patric::run(g, &o, &patric_ranges, HubThreshold::Auto).unwrap();
        assert_eq!(pr.triangles, expect, "patric P={p}");
        assert_eq!(pr.metrics.partition_accounting_divergence(), None, "patric mem P={p}");

        if p >= 2 {
            let r = dynamic_lb::run(&o, p, dynamic_lb::Options::default()).unwrap();
            assert_eq!(r.triangles, expect, "dynamic P={p}");
        }
    }
}

#[test]
fn classics_all_algorithms() {
    assert_all_agree(&classic::karate(), 45, &[1, 2, 5]);
    assert_all_agree(&classic::complete(20), 1140, &[3, 7]);
    assert_all_agree(&classic::petersen(), 0, &[2, 4]);
    assert_all_agree(&classic::wheel(12), 12, &[3]);
}

#[test]
fn skewed_pa_graph_all_algorithms() {
    let g = tricount::gen::pa::preferential_attachment(2_000, 16, &mut Rng::seeded(21));
    let o = Oriented::from_graph(&g);
    let expect = node_iterator::count(&o);
    assert!(expect > 1000, "PA graph should be triangle-rich, got {expect}");
    assert_all_agree(&g, expect, &[2, 6, 11]);
}

#[test]
fn rmat_heavy_tail_all_algorithms() {
    let g = tricount::gen::rmat::rmat(11, 10, Default::default(), &mut Rng::seeded(31));
    let o = Oriented::from_graph(&g);
    let expect = node_iterator::count(&o);
    assert_all_agree(&g, expect, &[4, 9]);
}

#[test]
fn contact_network_all_algorithms() {
    let g = tricount::gen::geometric::miami_like(3_000, 20, &mut Rng::seeded(41));
    let o = Oriented::from_graph(&g);
    let expect = node_iterator::count(&o);
    assert_all_agree(&g, expect, &[5]);
}

#[test]
fn io_roundtrip_preserves_counts() {
    let g = tricount::gen::erdos_renyi::gnm(500, 3_000, &mut Rng::seeded(51));
    let dir = std::env::temp_dir().join("tricount_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("g.bin");
    io::write_binary(&g, &p).unwrap();
    let g2 = io::read_binary(&p).unwrap();
    let a = node_iterator::count(&Oriented::from_graph(&g));
    let b = node_iterator::count(&Oriented::from_graph(&g2));
    assert_eq!(a, b);
}

#[test]
fn more_processors_than_nodes() {
    // Degenerate but must not crash or miscount.
    let g = classic::complete(6);
    assert_all_agree(&g, 20, &[10, 20]);
}

#[test]
fn config_driven_run_matches() {
    // The launcher path: config file → workload → algorithm.
    let mut cfg = tricount::config::RunConfig::default();
    cfg.set("workload", "pa:800:6").unwrap();
    cfg.set("procs", "5").unwrap();
    let g = cfg.build_graph().unwrap();
    let o = Oriented::from_graph(&g);
    let expect = node_iterator::count(&o);
    let prefix = prefix_sums(&cost_vector(&o, CostFn::SurrogateNew));
    let ranges = balanced_ranges(&prefix, cfg.procs);
    assert_eq!(
        surrogate::run(&o, &ranges, cfg.hub_threshold).unwrap().triangles,
        expect
    );
}

/// The issue's required matrix: owned-partition counts equal the
/// shared-view oracle (`seq::node_iterator` over the full graph) across
/// PA / R-MAT / ER at P ∈ {1, 2, 8}, for all three §IV drivers.
#[test]
fn owned_partitions_match_shared_oracle_across_generators() {
    let mut rng = Rng::seeded(2024);
    let graphs: Vec<(&str, Csr)> = vec![
        ("pa", tricount::gen::pa::preferential_attachment(1500, 8, &mut rng)),
        ("rmat", tricount::gen::rmat::rmat(9, 6, Default::default(), &mut rng)),
        ("er", tricount::gen::erdos_renyi::gnm(1200, 6000, &mut rng)),
    ];
    for (name, g) in &graphs {
        let o = Oriented::from_graph(g);
        let expect = node_iterator::count(&o);
        let prefix = prefix_sums(&cost_vector(&o, CostFn::SurrogateNew));
        for p in [1usize, 2, 8] {
            let ranges = balanced_ranges(&prefix, p);
            let s = surrogate::run(&o, &ranges, HubThreshold::Auto).unwrap();
            assert_eq!(s.triangles, expect, "{name} surrogate P={p}");
            assert_eq!(s.metrics.partition_accounting_divergence(), None, "{name} P={p}");
            let d = direct::run(&o, &ranges, HubThreshold::Auto).unwrap();
            assert_eq!(d.triangles, expect, "{name} direct P={p}");
            let pr = patric::run(g, &o, &ranges, HubThreshold::Auto).unwrap();
            assert_eq!(pr.triangles, expect, "{name} patric P={p}");
            // Non-overlapping residency bounded by PATRIC's overlap.
            assert!(
                s.metrics.max_partition_bytes() <= pr.metrics.max_partition_bytes(),
                "{name} P={p}: ours must not exceed overlap"
            );
        }
    }
}
