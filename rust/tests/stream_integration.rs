//! Acceptance tests for the `stream/` subsystem (ISSUE 1): a PA(100k, 16)
//! workload streamed as ≥ 50 batches of 1k mixed inserts/deletes must end
//! with the incremental count exactly matching a from-scratch Fig-1
//! recount — at 1 rank and at 8 ranks.

use tricount::gen::rng::Rng;
use tricount::graph::ordering::Oriented;
use tricount::seq::node_iterator;
use tricount::stream::compact::CompactionPolicy;
use tricount::stream::parallel::{self, StreamOptions};
use tricount::stream::window;
use tricount::stream::workload::{edge_stream, StreamSpec};

#[test]
fn pa100k_50_batches_exact_at_1_and_8_ranks() {
    let g = tricount::gen::pa::preferential_attachment(100_000, 16, &mut Rng::seeded(42));
    let spec = StreamSpec {
        base_fraction: 0.5,
        batch_size: 1_000,
        batches: 50,
        delete_fraction: 0.25,
    };
    let w = edge_stream(&g, &spec, &mut Rng::seeded(7));
    assert_eq!(w.batches.len(), 50);
    assert_eq!(w.updates, 50_000, "PA(100k,16) has plenty of edges to stream");

    let mut counts = Vec::new();
    for p in [1usize, 8] {
        let r = parallel::run(&w.base, &w.batches, p, StreamOptions::default()).unwrap();
        let recount = node_iterator::count(&Oriented::from_graph(&r.final_graph));
        assert_eq!(
            r.final_triangles, recount,
            "P={p}: incremental count must match from-scratch node-iterator recount"
        );
        assert!(r.compactions > 0, "default policy must compact over 50 batches");
        let eff: u64 = r.effective_updates();
        assert!(eff > 0 && eff <= 50_000);
        counts.push(r.final_triangles);
    }
    assert_eq!(counts[0], counts[1], "rank count must not affect the result");
}

#[test]
fn windowed_pa_stream_exercises_deletions_at_scale() {
    // Smaller PA graph, window of 5 batches: past batch 5 every batch
    // carries ~batch_size expiries, so deletions dominate.
    let g = tricount::gen::pa::preferential_attachment(20_000, 16, &mut Rng::seeded(1));
    let spec = StreamSpec {
        base_fraction: 0.4,
        batch_size: 500,
        batches: 25,
        delete_fraction: 0.0, // all raw updates are inserts; the window deletes
    };
    let w = edge_stream(&g, &spec, &mut Rng::seeded(2));
    let expanded = window::expand(&w.base, &w.batches, 5);
    let deletes_emitted: usize = expanded
        .iter()
        .flat_map(|b| &b.updates)
        .filter(|u| !u.insert)
        .count();
    assert!(deletes_emitted >= 9_000, "window must generate mass deletions");

    for p in [1usize, 4] {
        let r = parallel::run(&w.base, &expanded, p, StreamOptions::default()).unwrap();
        let recount = node_iterator::count(&Oriented::from_graph(&r.final_graph));
        assert_eq!(r.final_triangles, recount, "P={p}");
        // The window retains ≤ 5 batches of streamed edges.
        assert!(
            r.final_graph.num_edges() <= w.base.num_edges() + 5 * 500,
            "window bound violated"
        );
    }
}

#[test]
fn compaction_cadence_does_not_change_results() {
    let g = tricount::gen::pa::preferential_attachment(5_000, 12, &mut Rng::seeded(3));
    let spec = StreamSpec {
        base_fraction: 0.6,
        batch_size: 200,
        batches: 20,
        delete_fraction: 0.3,
    };
    let w = edge_stream(&g, &spec, &mut Rng::seeded(4));
    let run_with = |policy: CompactionPolicy| {
        parallel::run(&w.base, &w.batches, 3, StreamOptions { policy })
            .unwrap()
            .final_triangles
    };
    let never = run_with(CompactionPolicy::never());
    let eager = run_with(CompactionPolicy { every_batches: 1, overlay_ratio: 0.0 });
    let sized = run_with(CompactionPolicy { every_batches: 0, overlay_ratio: 0.01 });
    assert_eq!(never, eager);
    assert_eq!(never, sized);
}
