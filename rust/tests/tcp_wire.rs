//! Wire-protocol corruption suite for the socket fabric (`comm::tcp`,
//! DESIGN.md §15): every malformed byte stream must produce a typed,
//! deterministic error — never a panic, never a hang, never a silently
//! wrong frame. The codec is total over arbitrary input; these tests
//! sweep every truncation point exhaustively and fuzz the rest through
//! the in-crate property harness.

use std::io::{Cursor, Read, Write};

use tricount::comm::tcp::{
    encode_frame, encode_hello, read_frame, read_hello, read_seq, write_seq, RawFrame,
    FRAME_HEADER_BYTES, HELLO_BYTES, MAGIC, MAX_FRAME_BYTES, WIRE_VERSION,
};
use tricount::comm::transport::{Wire, WireReader};
use tricount::error::Error;

fn comm_msg(e: Error) -> String {
    match e {
        Error::Comm(m) => m,
        other => panic!("expected Error::Comm, got {other:?}"),
    }
}

fn config_msg(e: Error) -> String {
    match e {
        Error::Config(m) => m,
        other => panic!("expected Error::Config, got {other:?}"),
    }
}

// ---------------------------------------------------------------------------
// Frames
// ---------------------------------------------------------------------------

#[test]
fn frame_roundtrips_at_every_payload_size_class() {
    for len in [0usize, 1, 7, 20, 255, 4096] {
        let payload: Vec<u8> = (0..len).map(|i| (i * 31 % 251) as u8).collect();
        let bytes = encode_frame(3, 1, 6, 42, &payload);
        assert_eq!(bytes.len(), FRAME_HEADER_BYTES + len);
        let mut c = Cursor::new(&bytes);
        let f = read_frame(&mut c).unwrap().expect("one frame in");
        assert_eq!(
            f,
            RawFrame { src: 3, dst: 1, tag: 6, control: 42, payload },
            "payload size {len}"
        );
        // The stream is now at a frame boundary: clean EOF, not an error.
        assert!(read_frame(&mut c).unwrap().is_none(), "payload size {len}");
    }
}

/// Exhaustive truncation sweep: cutting a valid frame at *any* interior
/// byte is a mid-stream disconnect ([`Error::Comm`]); cutting at offset 0
/// is a clean EOF (`Ok(None)`). Stronger than random fuzz — every cut
/// point is visited.
#[test]
fn every_truncation_point_is_a_comm_error() {
    let bytes = encode_frame(0, 2, 0, 0, b"nine-byte");
    assert!(read_frame(&mut Cursor::new(&bytes[..0])).unwrap().is_none());
    for cut in 1..bytes.len() {
        match read_frame(&mut Cursor::new(&bytes[..cut])) {
            Err(e) => {
                let msg = comm_msg(e);
                assert!(msg.contains("disconnect"), "cut {cut}: {msg}");
            }
            Ok(other) => panic!("cut at {cut} must fail, got {other:?}"),
        }
    }
}

/// A corrupt length prefix fails *before* the payload allocation: a frame
/// header claiming `u32::MAX` bytes must be rejected by the cap, not
/// attempted.
#[test]
fn oversize_length_prefix_fails_before_allocation() {
    for claimed in [MAX_FRAME_BYTES as u32 + 1, u32::MAX] {
        let mut bytes = encode_frame(0, 1, 0, 0, &[]);
        bytes[16..20].copy_from_slice(&claimed.to_le_bytes());
        let msg = comm_msg(read_frame(&mut Cursor::new(&bytes)).unwrap_err());
        assert!(msg.contains("exceeds"), "{msg}");
    }
    // The cap itself is inclusive: a header claiming exactly MAX_FRAME_BYTES
    // passes validation and then fails as a truncated payload.
    let mut bytes = encode_frame(0, 1, 0, 0, &[]);
    bytes[16..20].copy_from_slice(&(MAX_FRAME_BYTES as u32).to_le_bytes());
    let msg = comm_msg(read_frame(&mut Cursor::new(&bytes)).unwrap_err());
    assert!(msg.contains("disconnect"), "{msg}");
}

/// Interleaved frames on one stream decode in order with nothing carried
/// between them — the non-overtaking base case.
#[test]
fn back_to_back_frames_decode_in_order() {
    let mut stream = Vec::new();
    for i in 0..5u32 {
        stream.extend_from_slice(&encode_frame(i, 0, i % 3, i * 10, &vec![i as u8; i as usize]));
    }
    let mut c = Cursor::new(&stream);
    for i in 0..5u32 {
        let f = read_frame(&mut c).unwrap().unwrap();
        assert_eq!((f.src, f.tag, f.control, f.payload.len()), (i, i % 3, i * 10, i as usize));
    }
    assert!(read_frame(&mut c).unwrap().is_none());
}

// ---------------------------------------------------------------------------
// Rendezvous hello
// ---------------------------------------------------------------------------

#[test]
fn hello_roundtrip_and_field_extraction() {
    let b = encode_hello(0xDEAD_BEEF_0BAD_F00D, 3, 8);
    assert_eq!(b.len(), HELLO_BYTES);
    let h = read_hello(&mut Cursor::new(&b)).unwrap();
    assert_eq!((h.job_id, h.rank, h.procs), (0xDEAD_BEEF_0BAD_F00D, 3, 8));
}

/// A peer that is not a tricount build is a *deployment* mistake, not a
/// transient wire fault: bad magic and bad version are `Error::Config`.
#[test]
fn foreign_magic_and_version_are_config_errors() {
    let mut b = encode_hello(1, 0, 2);
    b[0] ^= 0xFF;
    let msg = config_msg(read_hello(&mut Cursor::new(&b)).unwrap_err());
    assert!(msg.contains("magic") && msg.contains("not a tricount peer"), "{msg}");

    let mut b = encode_hello(1, 0, 2);
    b[4..8].copy_from_slice(&(WIRE_VERSION + 1).to_le_bytes());
    let msg = config_msg(read_hello(&mut Cursor::new(&b)).unwrap_err());
    assert!(msg.contains("wire version mismatch"), "{msg}");

    // Sanity: the constants the protocol pins.
    assert_eq!(MAGIC, 0x5452_4943); // "TRIC" LE
    assert_eq!(WIRE_VERSION, 1);
}

#[test]
fn truncated_hello_is_a_comm_error_at_every_cut() {
    let b = encode_hello(7, 1, 4);
    for cut in 0..b.len() {
        let msg = comm_msg(read_hello(&mut Cursor::new(&b[..cut])).unwrap_err());
        assert!(msg.contains("hello"), "cut {cut}: {msg}");
    }
}

// ---------------------------------------------------------------------------
// Payload sequences (the result-allgather body)
// ---------------------------------------------------------------------------

#[test]
fn seq_roundtrip_rejects_trailing_garbage() {
    let items: Vec<u64> = vec![0, u64::MAX, 0x0123_4567_89AB_CDEF];
    let mut buf = Vec::new();
    write_seq(&items, &mut buf);
    let mut r = WireReader::new(&buf);
    assert_eq!(read_seq::<u64>(&mut r).unwrap(), items);
    r.finish().expect("exact consumption");

    buf.push(0xAA);
    let mut r = WireReader::new(&buf);
    assert_eq!(read_seq::<u64>(&mut r).unwrap(), items);
    let msg = comm_msg(r.finish().unwrap_err());
    assert!(msg.contains("trailing"), "{msg}");
}

#[test]
fn seq_with_corrupt_count_fails_before_allocation() {
    let mut buf = Vec::new();
    write_seq(&[1u64, 2, 3], &mut buf);
    buf[0..8].copy_from_slice(&u64::MAX.to_le_bytes());
    let mut r = WireReader::new(&buf);
    let msg = comm_msg(read_seq::<u64>(&mut r).unwrap_err());
    assert!(msg.contains("length prefix"), "{msg}");
}

// ---------------------------------------------------------------------------
// Randomized totality (in-crate property harness)
// ---------------------------------------------------------------------------

/// Decoding arbitrary bytes as a frame or hello never panics and never
/// fabricates an over-long read: either a value consuming exactly what
/// its header claims, or a typed error.
#[test]
fn random_bytes_never_panic_the_decoders() {
    tricount::prop::quickcheck("tcp wire totality", |rng, _| {
        let len = rng.below_usize(96);
        let bytes: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
        match read_frame(&mut Cursor::new(&bytes)) {
            Ok(Some(f)) => {
                if FRAME_HEADER_BYTES + f.payload.len() > bytes.len() {
                    return Err(format!(
                        "decoded {}-byte payload from {}-byte input",
                        f.payload.len(),
                        bytes.len()
                    ));
                }
            }
            Ok(None) => {
                if !bytes.is_empty() {
                    return Err("Ok(None) on non-empty stream".into());
                }
            }
            Err(Error::Comm(_)) => {}
            Err(other) => return Err(format!("unexpected error class: {other:?}")),
        }
        match read_hello(&mut Cursor::new(&bytes)) {
            Ok(_) | Err(Error::Comm(_)) | Err(Error::Config(_)) => Ok(()),
            Err(other) => Err(format!("hello: unexpected error class: {other:?}")),
        }
    });
}

/// Single-bit corruption of a valid frame stream: decoding stays total,
/// and corruption outside the payload-length word can never make the
/// reader consume more bytes than the original stream held.
#[test]
fn bit_flips_never_panic_or_overread() {
    tricount::prop::quickcheck("tcp frame bit flips", |rng, _| {
        let payload: Vec<u8> = (0..rng.below_usize(40)).map(|_| rng.below(256) as u8).collect();
        let mut bytes = encode_frame(
            rng.below(8) as u32,
            rng.below(8) as u32,
            rng.below(8) as u32,
            rng.below(1 << 16) as u32,
            &payload,
        );
        let bit = rng.below((bytes.len() * 8) as u64) as usize;
        bytes[bit / 8] ^= 1 << (bit % 8);
        match read_frame(&mut Cursor::new(&bytes)) {
            Ok(Some(f)) => {
                // A flip in the length word may shorten the frame; it can
                // never lengthen it past the input without erroring.
                if f.payload.len() > payload.len() {
                    return Err("bit flip grew the decoded payload".into());
                }
                Ok(())
            }
            Ok(None) => Err("Ok(None) on non-empty stream".into()),
            Err(Error::Comm(_)) => Ok(()),
            Err(other) => Err(format!("unexpected error class: {other:?}")),
        }
    });
}

// ---------------------------------------------------------------------------
// Real sockets
// ---------------------------------------------------------------------------

/// A peer that dies mid-frame on a real TCP stream surfaces as the same
/// deterministic `Error::Comm` the cursor sweeps produce — the reader
/// does not block on the missing bytes.
#[test]
fn mid_stream_disconnect_on_a_live_socket_is_a_comm_error() {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let writer = std::thread::spawn(move || {
        let mut s = std::net::TcpStream::connect(addr).unwrap();
        // Header promises 100 payload bytes; deliver 10, then vanish.
        let frame = encode_frame(1, 0, 0, 0, &[0u8; 100]);
        s.write_all(&frame[..FRAME_HEADER_BYTES + 10]).unwrap();
        // Drop closes the socket.
    });
    let (mut conn, _) = listener.accept().unwrap();
    let msg = comm_msg(read_frame(&mut conn).unwrap_err());
    assert!(msg.contains("disconnect"), "{msg}");
    writer.join().unwrap();
}

/// A frame written through a real socket in arbitrarily small chunks
/// (exercising short `read` returns) still reassembles exactly.
#[test]
fn dribbled_frame_reassembles_over_a_live_socket() {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let payload: Vec<u8> = (0..300).map(|i| (i % 256) as u8).collect();
    let frame = encode_frame(2, 0, 6, 1, &payload);
    let chunks = frame.clone();
    let writer = std::thread::spawn(move || {
        let mut s = std::net::TcpStream::connect(addr).unwrap();
        s.set_nodelay(true).unwrap();
        for chunk in chunks.chunks(7) {
            s.write_all(chunk).unwrap();
            s.flush().unwrap();
        }
        // Half-close the write side so the reader sees clean EOF after.
        s.shutdown(std::net::Shutdown::Write).unwrap();
        // Keep the socket alive until the reader drains it.
        let mut sink = Vec::new();
        let _ = s.read_to_end(&mut sink);
    });
    let (mut conn, _) = listener.accept().unwrap();
    let f = read_frame(&mut conn).unwrap().unwrap();
    assert_eq!(f, RawFrame { src: 2, dst: 0, tag: 6, control: 1, payload });
    assert!(read_frame(&mut conn).unwrap().is_none());
    writer.join().unwrap();
}

/// `CommMetrics` — the result-allgather body — survives a wire roundtrip
/// bit-exactly, including the socket fabric's own `wire_overhead_bytes`
/// counter.
#[test]
fn comm_metrics_roundtrip_preserves_wire_overhead() {
    let m = tricount::comm::metrics::CommMetrics {
        messages_sent: 17,
        bytes_sent: 4096,
        wire_overhead_bytes: 620,
        frames_sent: 3,
        ..Default::default()
    };
    let bytes = m.to_bytes();
    let back = tricount::comm::metrics::CommMetrics::from_bytes(&bytes).unwrap();
    assert_eq!(back.wire_overhead_bytes, 620);
    assert_eq!(back.messages_sent, 17);
    assert_eq!(back.bytes_sent, 4096);
    // Truncation of the metrics body is as total as the frame codec.
    for cut in [0, 1, bytes.len() / 2, bytes.len() - 1] {
        assert!(
            tricount::comm::metrics::CommMetrics::from_bytes(&bytes[..cut]).is_err(),
            "cut {cut}"
        );
    }
}
