//! End-to-end check for the `TRICOUNT_RECV_GUARD_SECS` startup contract:
//! a malformed override is an [`Error::Config`] *before any rank spawns*,
//! on both the channel and the virtual fabric — not a silent fallback to
//! the 30-minute default, and not a mid-run surprise.
//!
//! This lives in its own integration-test binary (own process) because it
//! mutates the environment; in the unit-test binary it would race every
//! other test that launches a cluster.

use tricount::comm::Cluster;
use tricount::error::Error;
use tricount::testkit::sim::try_run_sim;
use tricount::testkit::SimConfig;

#[test]
fn malformed_recv_guard_fails_startup_on_both_fabrics() {
    std::env::set_var("TRICOUNT_RECV_GUARD_SECS", "bogus");

    let channel = Cluster::try_run::<u64, (), _>(2, |_| Ok(()));
    match channel {
        Err(Error::Config(msg)) => {
            assert!(msg.contains("TRICOUNT_RECV_GUARD_SECS"), "{msg}");
            assert!(msg.contains("bogus"), "{msg}");
        }
        other => panic!("channel fabric: expected config error at startup, got {other:?}"),
    }

    let (sim, _trace) = try_run_sim::<u64, (), _>(2, &SimConfig::adversarial(1), |_| Ok(()));
    match sim {
        Err(Error::Config(msg)) => {
            assert!(msg.contains("TRICOUNT_RECV_GUARD_SECS"), "{msg}")
        }
        other => panic!("virtual fabric: expected config error at startup, got {other:?}"),
    }

    // A well-formed override passes the same gate.
    std::env::set_var("TRICOUNT_RECV_GUARD_SECS", "900");
    Cluster::try_run::<u64, (), _>(2, |_| Ok(())).expect("valid guard must pass");
    std::env::remove_var("TRICOUNT_RECV_GUARD_SECS");
}
