//! Tier-1 conformance gate: every counting path, over the seeded virtual
//! transport, against the sequential oracle, across adversarial schedules
//! (ISSUE 5 acceptance criteria; DESIGN.md §10).
//!
//! The schedule count per (path, workload, P) config defaults to 16 and
//! can be scaled with `TRICOUNT_CONFORMANCE_SEEDS` (>= 1) for quick local
//! iterations; CI runs the default.

use tricount::testkit::conformance::{run, ConformanceReport, Options, Path};
use tricount::testkit::sched::{FaultPlan, SimConfig};
use tricount::testkit::sim::Fabric;

fn seeds_from_env(default: u64) -> u64 {
    std::env::var("TRICOUNT_CONFORMANCE_SEEDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .filter(|&s| s > 0)
        .unwrap_or(default)
}

fn assert_clean(r: &ConformanceReport) {
    assert!(
        r.ok(),
        "{} conformance violation(s):\n{}",
        r.failures.len(),
        r.failures.join("\n")
    );
}

/// The acceptance matrix: all six paths × PA/R-MAT/ER × P∈{2,4,8} ×
/// ≥16 adversarial schedules per config, every cell run twice (replay).
#[test]
fn full_adversarial_matrix_matches_oracle() {
    let opts = Options { seeds: seeds_from_env(16), faults: false, ..Default::default() };
    let r = run(&opts).unwrap();
    assert_clean(&r);
    let expected_configs = opts.workloads.len() * opts.procs.len() * opts.paths.len();
    assert_eq!(r.configs.len(), expected_configs);
    assert_eq!(r.cells, expected_configs as u64 * opts.seeds);
    // Adversarial schedules must actually differ: within a config the
    // per-seed traces are combined, and across configs the hashes of a
    // chatty path must not all collapse to one value.
    let surrogate_hashes: std::collections::BTreeSet<u64> = r
        .configs
        .iter()
        .filter(|c| c.path == "surrogate")
        .map(|c| c.hash)
        .collect();
    assert!(surrogate_hashes.len() > 1, "surrogate configs all hashed identically");
}

/// Fault pass: rank death errors out on every path; a lost message trips
/// the virtual recv guard on the request/reply protocols; both replay
/// deterministically.
#[test]
fn fault_injection_errors_deterministically() {
    let opts = Options {
        seeds: 1,
        workloads: vec!["pa:160:6".into()],
        procs: vec![4],
        faults: true,
        ..Default::default()
    };
    let r = run(&opts).unwrap();
    assert_clean(&r);
    // death check per path + drop check per p2p path.
    let p2p = Path::ALL.iter().filter(|p| p.has_p2p()).count() as u64;
    assert_eq!(r.fault_checks, Path::ALL.len() as u64 + p2p);
}

/// Same seed ⇒ same matrix hash across two *separate* suite invocations —
/// the in-process version of the CI double-run diff.
#[test]
fn matrix_hash_replays_across_invocations() {
    let opts = Options {
        seeds: 3,
        workloads: vec!["rmat:7:4".into()],
        procs: vec![2, 4],
        faults: false,
        ..Default::default()
    };
    let a = run(&opts).unwrap();
    let b = run(&opts).unwrap();
    assert_clean(&a);
    assert_eq!(a.matrix_hash, b.matrix_hash);
    assert_eq!(
        a.configs.iter().map(|c| c.hash).collect::<Vec<_>>(),
        b.configs.iter().map(|c| c.hash).collect::<Vec<_>>()
    );
}

/// The virtual fabric agrees with the production channel fabric on the
/// same protocol (sanity: the Transport extraction changed nothing).
#[test]
fn virtual_and_channel_fabrics_agree_on_surrogate() {
    use tricount::adj::HubThreshold;
    use tricount::algo::surrogate;
    use tricount::config::CostFn;
    use tricount::graph::ordering::Oriented;
    use tricount::partition::balance::balanced_ranges;
    use tricount::partition::cost::{cost_vector, prefix_sums};

    let g = tricount::config::build_workload("pa:200:6", 1.0, 3).unwrap();
    let o = Oriented::from_graph(&g);
    let ranges = balanced_ranges(&prefix_sums(&cost_vector(&o, CostFn::SurrogateNew)), 4);
    let (chan, trace) = surrogate::run_on(&Fabric::Channel, &o, &ranges, HubThreshold::Auto);
    assert!(trace.is_none(), "channel fabric must not produce a trace");
    let cfg = SimConfig::adversarial(99);
    let (sim, trace) = surrogate::run_on(&Fabric::Sim(cfg), &o, &ranges, HubThreshold::Auto);
    let (chan, sim) = (chan.unwrap(), sim.unwrap());
    assert_eq!(chan.triangles, sim.triangles);
    assert_eq!(
        chan.triangles,
        tricount::seq::node_iterator::count(&o)
    );
    let t = trace.expect("virtual fabric must produce a trace");
    assert!(t.sends > 0 && t.delivered == t.sends);
}

/// The live-wire axis (ISSUE 10): the same acceptance matrix — every
/// path × workload × P∈{2,4,8} — each cell run as P OS processes over
/// loopback TCP, spawned from this test's own binary. Oracle equality and
/// per-tag-class byte conservation are asserted on the allgathered
/// metrics; every worker process also checks its own copy of the result
/// (the end-of-run allgather) and exits nonzero on disagreement.
#[test]
fn full_matrix_matches_oracle_over_loopback_tcp() {
    use tricount::testkit::conformance::{run_tcp_matrix, TcpOptions};
    let opts = TcpOptions::new(env!("CARGO_BIN_EXE_tricount"));
    let r = run_tcp_matrix(&opts).unwrap();
    assert_clean(&r);
    let expected =
        (opts.workloads.len() * opts.procs.len() * opts.paths.len()) as u64;
    assert_eq!(r.cells, expected);
}

/// A straggler rank (slow-rank fault) reschedules everything but moves no
/// counts — checked here on the dynamic load balancer, whose whole point
/// is tolerating exactly this.
#[test]
fn straggler_does_not_move_dynamic_lb_counts() {
    use std::sync::Arc;
    use tricount::algo::dynamic_lb::{self, Options as LbOptions};
    use tricount::graph::ordering::Oriented;

    let g = tricount::config::build_workload("er:220:5", 1.0, 5).unwrap();
    let o = Arc::new(Oriented::from_graph(&g));
    let oracle = tricount::seq::node_iterator::count(&o);
    for seed in 0..4 {
        let cfg = SimConfig::with_faults(seed, FaultPlan::slow_rank(2, 32));
        let (r, _) = dynamic_lb::run_on(&Fabric::Sim(cfg), &o, 4, LbOptions::default());
        assert_eq!(r.unwrap().triangles, oracle, "seed {seed}");
    }
}
