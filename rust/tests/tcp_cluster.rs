//! Process-level tests for the socket fabric (DESIGN.md §15): rendezvous
//! failures are deterministic typed errors that leave no orphan worker
//! processes, and the byte accounting a TCP cell reports is *identical*
//! to the in-process channel fabric's — the framing cost appears only in
//! the additive `wire_overhead_bytes` counter.
//!
//! Workers are real OS processes spawned from `CARGO_BIN_EXE_tricount`;
//! rank 0 always runs in this test process so errors and metrics come
//! back as values. Every spawned child is reaped with a wait-with-timeout
//! before a test returns.

use std::process::{Command, Stdio};
use std::time::{Duration, Instant};

use tricount::comm::tcp::TcpFabric;
use tricount::error::Error;
use tricount::testkit::conformance::{
    free_loopback_addr, reap_children, run_cell, run_tcp_cell, Path, TcpOptions,
};
use tricount::testkit::sim::Fabric;

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_tricount")
}

/// Spawn `worker --connect … -- conformance-cell` with an explicit rank /
/// procs / job id — the building block for the failure-injection tests.
fn spawn_worker(addr: &str, rank: usize, procs: usize, job_id: u64, join_ms: u64) -> std::process::Child {
    Command::new(bin())
        .args([
            "worker",
            "--connect",
            addr,
            "--rank",
            &rank.to_string(),
            "--procs",
            &procs.to_string(),
            "--job-id",
            &job_id.to_string(),
            "--join-timeout-ms",
            &join_ms.to_string(),
            "--",
            "conformance-cell",
            "--path",
            "surrogate",
            "--workload",
            "pa:160:6",
        ])
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn worker")
}

fn host_fabric(addr: &str, procs: usize, job_id: u64, join_ms: u64) -> Fabric {
    Fabric::Tcp(TcpFabric {
        connect: addr.to_string(),
        rank: 0,
        procs,
        job_id,
        join_timeout_ms: join_ms,
    })
}

fn config_msg(e: Error) -> String {
    match e {
        Error::Config(m) => m,
        other => panic!("expected Error::Config, got {other:?}"),
    }
}

/// Reap and assert every child *exited on its own* (any status) — i.e.
/// nothing was still running when the deadline hit. Returns the failure
/// strings for callers that also care about exit codes.
fn assert_no_orphans(mut children: Vec<(usize, std::process::Child)>, timeout: Duration) -> Vec<String> {
    let failures = reap_children(&mut children, timeout, false);
    for f in &failures {
        assert!(
            !f.contains("still running"),
            "orphaned worker had to be killed: {f}"
        );
    }
    failures
}

// ---------------------------------------------------------------------------
// Rendezvous failures
// ---------------------------------------------------------------------------

/// Two workers presenting the same rank: rank 0 rejects the roster with a
/// deterministic `Error::Config`, both workers are notified (or see EOF)
/// and exit without being killed.
#[test]
fn duplicate_rank_is_a_config_error_with_no_orphans() {
    let addr = free_loopback_addr().unwrap();
    let job = 0x10_0001;
    let children = vec![
        (1, spawn_worker(&addr, 1, 3, job, 15_000)),
        (1, spawn_worker(&addr, 1, 3, job, 15_000)),
    ];
    let err = run_cell(Path::Surrogate, "pa:160:6", 3, &host_fabric(&addr, 3, job, 15_000))
        .expect_err("duplicate rank must fail rendezvous");
    let msg = config_msg(err);
    assert!(msg.contains("duplicate rank 1"), "{msg}");
    // Rejected workers exit nonzero on their own — no kill needed.
    let failures = assert_no_orphans(children, Duration::from_secs(20));
    assert_eq!(failures.len(), 2, "both workers must exit nonzero: {failures:?}");
}

/// A roster that never completes: rank 0 gives up at the join timeout
/// naming the ranks that never arrived, and drops the joined worker's
/// socket so it unblocks and exits too.
#[test]
fn missing_rank_times_out_deterministically() {
    let addr = free_loopback_addr().unwrap();
    let job = 0x10_0002;
    // P=3 but only rank 1 ever dials in.
    let children = vec![(1, spawn_worker(&addr, 1, 3, job, 10_000))];
    let start = Instant::now();
    let err = run_cell(Path::Surrogate, "pa:160:6", 3, &host_fabric(&addr, 3, job, 1_500))
        .expect_err("missing rank must time out");
    let msg = config_msg(err);
    assert!(msg.contains("join timeout"), "{msg}");
    assert!(msg.contains("missing rank(s) 2"), "{msg}");
    // The timeout is honored, not a hang: well under the worker's own 10s.
    assert!(start.elapsed() < Duration::from_secs(8), "took {:?}", start.elapsed());
    let failures = assert_no_orphans(children, Duration::from_secs(20));
    assert_eq!(failures.len(), 1, "the joined worker must exit nonzero: {failures:?}");
}

/// A worker from a different launch (stale script, recycled port): its
/// hello carries the wrong job id and rank 0 rejects the roster; the
/// worker exits cleanly rather than counting into the wrong job.
#[test]
fn job_id_mismatch_is_rejected() {
    let addr = free_loopback_addr().unwrap();
    let children = vec![
        (1, spawn_worker(&addr, 1, 2, 0xAAAA, 15_000)), // wrong job id
    ];
    let err = run_cell(Path::Surrogate, "pa:160:6", 2, &host_fabric(&addr, 2, 0xBBBB, 15_000))
        .expect_err("job-id mismatch must fail rendezvous");
    let msg = config_msg(err);
    assert!(msg.contains("job-id mismatch"), "{msg}");
    let failures = assert_no_orphans(children, Duration::from_secs(20));
    assert_eq!(failures.len(), 1, "mismatched worker must exit nonzero: {failures:?}");
}

/// A worker whose host never exists: the dial retry loop is bounded by
/// the join timeout — the process exits nonzero on its own, quickly.
#[test]
fn worker_without_a_host_exits_within_its_join_timeout() {
    // Reserve-and-release a port so nothing is listening there.
    let addr = free_loopback_addr().unwrap();
    let children = vec![(1, spawn_worker(&addr, 1, 2, 1, 1_000))];
    let start = Instant::now();
    let failures = assert_no_orphans(children, Duration::from_secs(15));
    assert_eq!(failures.len(), 1, "worker must exit nonzero: {failures:?}");
    assert!(start.elapsed() < Duration::from_secs(12), "took {:?}", start.elapsed());
}

// ---------------------------------------------------------------------------
// Byte-accounting equivalence (channel fabric vs loopback TCP)
// ---------------------------------------------------------------------------

/// The socket fabric accounts exactly like the channel fabric: every
/// deterministic per-rank counter matches between an in-process run and a
/// 4-process loopback run of the same cell, and the TCP framing cost
/// shows up *only* in `wire_overhead_bytes` (additive, zero in-process).
#[test]
fn tcp_and_channel_fabrics_account_identically() {
    let opts = TcpOptions::new(bin());
    for (i, path) in [Path::Surrogate, Path::Direct, Path::Tile2d].into_iter().enumerate() {
        let spec = "pa:160:6";
        let p = 4;
        let chan = run_cell(path, spec, p, &Fabric::Channel).unwrap();
        let tcp = run_tcp_cell(&opts, path, spec, p, 0x2000_0000 + i as u64).unwrap();

        assert_eq!(chan.count, chan.oracle, "{path:?}: channel count");
        assert_eq!(tcp.count, tcp.oracle, "{path:?}: tcp count");
        assert_eq!(chan.count, tcp.count, "{path:?}: fabrics disagree");

        assert_eq!(chan.metrics.per_rank.len(), p);
        assert_eq!(tcp.metrics.per_rank.len(), p);
        for r in 0..p {
            let (c, t) = (&chan.metrics.per_rank[r], &tcp.metrics.per_rank[r]);
            let label = format!("{path:?} rank {r}");
            assert_eq!(c.messages_sent, t.messages_sent, "{label}: messages_sent");
            assert_eq!(c.messages_received, t.messages_received, "{label}: messages_received");
            assert_eq!(c.bytes_sent, t.bytes_sent, "{label}: bytes_sent");
            assert_eq!(c.control_sent, t.control_sent, "{label}: control_sent");
            assert_eq!(c.control_received, t.control_received, "{label}: control_received");
            assert_eq!(c.frames_sent, t.frames_sent, "{label}: frames_sent");
            assert_eq!(c.frames_received, t.frames_received, "{label}: frames_received");
            assert_eq!(c.coalesced_sent, t.coalesced_sent, "{label}: coalesced_sent");
            assert_eq!(c.coalesced_received, t.coalesced_received, "{label}: coalesced_received");
            assert_eq!(c.row_bcast_sent, t.row_bcast_sent, "{label}: row_bcast_sent");
            assert_eq!(c.col_bcast_sent, t.col_bcast_sent, "{label}: col_bcast_sent");

            // Framing cost: strictly additive, never claimed in-process.
            assert_eq!(c.wire_overhead_bytes, 0, "{label}: channel fabric claims framing bytes");
            if t.messages_sent + t.control_sent > 0 {
                assert!(t.wire_overhead_bytes > 0, "{label}: tcp rank sent envelopes for free");
            }
        }

        // Conservation holds on the allgathered TCP metrics too.
        let violations = tricount::testkit::conformance::conservation_violations(&tcp.metrics);
        assert!(violations.is_empty(), "{path:?}: {violations:?}");
    }
}

/// Every process in a TCP cell receives the identical allgathered result:
/// a worker checks its own copy against the oracle (and exits nonzero on
/// mismatch), so `run_tcp_cell` succeeding certifies *every* rank's view,
/// not just rank 0's. This runs one extra path (dynamic-lb, the
/// coordinator/worker protocol) end-to-end over real sockets.
#[test]
fn dynamic_lb_counts_over_real_sockets() {
    let opts = TcpOptions::new(bin());
    let out = run_tcp_cell(&opts, Path::DynamicLb, "er:220:5", 4, 0x3000_0001).unwrap();
    assert_eq!(out.count, out.oracle);
}
