//! Integration tests for the XLA/PJRT runtime path: load the AOT artifacts
//! produced by `make artifacts` and validate counts against the sparse
//! kernel and closed forms. Skips (with a notice) when artifacts are absent
//! so `cargo test` works before `make artifacts`; `make test` always builds
//! artifacts first.

use std::sync::Arc;

use tricount::graph::classic;
use tricount::graph::ordering::Oriented;
use tricount::runtime::{artifact, engine::Engine};
use tricount::seq::node_iterator;
use tricount::tensor::core_extract::DenseCore;
use tricount::tensor::{hybrid, pack};

fn artifacts_dir() -> Option<String> {
    let dir = std::env::var("TRICOUNT_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let found = artifact::discover(&dir).unwrap_or_default();
    if found.is_empty() {
        eprintln!("[skip] no artifacts in `{dir}` — run `make artifacts`");
        None
    } else {
        Some(dir)
    }
}

#[test]
fn artifact_counts_k128() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::cpu().unwrap();
    let arts = artifact::discover(&dir).unwrap();
    let art = artifact::pick(&arts, 128).unwrap();
    let counter = engine.load_dense_counter(&art.path, art.n).unwrap();

    // K_128 packed as a strictly-upper-triangular block.
    let g = classic::complete(128);
    let o = Oriented::from_graph(&g);
    let core = DenseCore::extract(&o, 128);
    let m = pack::pack_core(&o, &core, art.n);
    let got = counter.count(&m).unwrap();
    assert_eq!(got, 128 * 127 * 126 / 6);
}

#[test]
fn artifact_matches_sparse_on_random_graphs() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::cpu().unwrap();
    let arts = artifact::discover(&dir).unwrap();
    let art = artifact::pick(&arts, 128).unwrap();
    let counter = engine.load_dense_counter(&art.path, art.n).unwrap();

    let mut rng = tricount::gen::rng::Rng::seeded(1234);
    for density in [100usize, 800, 3000] {
        let g = tricount::gen::erdos_renyi::gnm(120, density, &mut rng);
        let o = Oriented::from_graph(&g);
        let core = DenseCore::extract(&o, 120);
        let m = pack::pack_core(&o, &core, art.n);
        let dense = counter.count(&m).unwrap();
        let sparse = node_iterator::count(&o);
        assert_eq!(dense, sparse, "density {density}");
    }
}

#[test]
fn hybrid_with_engine_is_exact() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::cpu().unwrap();
    let g = tricount::gen::pa::preferential_attachment(
        5_000,
        12,
        &mut tricount::gen::rng::Rng::seeded(9),
    );
    let o = Arc::new(Oriented::from_graph(&g));
    let expect = node_iterator::count(&o);
    for k in [0usize, 64, 128, 500] {
        let r = hybrid::count_with_engine(&o, &engine, &dir, k).unwrap();
        assert_eq!(r.triangles, expect, "core size {k}");
        if k >= 64 {
            assert!(r.dense_triangles > 0, "PA dense core should contain triangles");
        }
    }
}

#[test]
fn all_block_sizes_agree() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::cpu().unwrap();
    let arts = artifact::discover(&dir).unwrap();
    assert!(arts.len() >= 2, "expect multiple artifact sizes");
    let g = classic::complete(100);
    let o = Oriented::from_graph(&g);
    let core = DenseCore::extract(&o, 100);
    let expect = 100 * 99 * 98 / 6;
    for art in &arts {
        let counter = engine.load_dense_counter(&art.path, art.n).unwrap();
        let m = pack::pack_core(&o, &core, art.n);
        assert_eq!(counter.count(&m).unwrap(), expect, "block {}", art.n);
    }
}

#[test]
fn karate_hybrid_through_xla() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::cpu().unwrap();
    let g = classic::karate();
    let o = Arc::new(Oriented::from_graph(&g));
    let r = hybrid::count_with_engine(&o, &engine, &dir, 16).unwrap();
    assert_eq!(r.triangles, classic::KARATE_TRIANGLES);
    assert_eq!(r.dense_triangles + r.sparse_triangles, classic::KARATE_TRIANGLES);
}
