//! Overhead gate for the ft/ supervision layer: a fault-free supervised
//! run (checkpoint store installed, progress published at every phase
//! boundary, liveness bookkeeping on) must cost < 3% over the plain
//! unsupervised driver — the acceptance budget the CI release run
//! enforces. `#[ignore]`d by default: it is a timing assertion and only
//! meaningful in release mode on a quiet machine
//! (`cargo test --release --test ft_overhead -- --ignored`).

use std::sync::Arc;
use std::time::Instant;

use tricount::adj::HubThreshold;
use tricount::algo::surrogate;
use tricount::config::CostFn;
use tricount::ft::{supervise, FaultPolicy, Job};
use tricount::gen::{pa, rng::Rng};
use tricount::graph::ordering::Oriented;
use tricount::partition::balance::balanced_ranges;
use tricount::partition::cost::{cost_vector, prefix_sums};
use tricount::testkit::Fabric;

/// Min-of-samples timing. Min (not median) because scheduler noise only
/// ever adds time; the minimum is the best estimate of the true cost.
fn min_secs<F: FnMut() -> u64>(samples: usize, mut f: F) -> f64 {
    let mut sink = f(); // warmup
    let mut best = f64::INFINITY;
    for _ in 0..samples {
        let t0 = Instant::now();
        sink = sink.wrapping_add(f());
        best = best.min(t0.elapsed().as_secs_f64());
    }
    std::hint::black_box(sink);
    best
}

#[test]
#[ignore = "timing gate; run in release via CI (ft overhead step)"]
fn fault_free_supervision_overhead_under_3_percent() {
    let g = pa::preferential_attachment(30_000, 16, &mut Rng::seeded(7));
    let o = Arc::new(Oriented::from_graph_with(&g, HubThreshold::Auto));
    let p = 4;
    let ranges = balanced_ranges(&prefix_sums(&cost_vector(&o, CostFn::SurrogateNew)), p);
    let job = Job::Surrogate { graph: &o, cost: CostFn::SurrogateNew, hub: HubThreshold::Auto };

    // Sanity first: the supervised run is a no-op wrapper when fault-free —
    // same count, zero recovery attempts.
    let oracle = surrogate::run(&o, &ranges, HubThreshold::Auto).unwrap().triangles;
    let r = supervise(&job, &Fabric::Channel, p, FaultPolicy::Recover).unwrap();
    assert_eq!(r.count, oracle, "supervised count must match the plain driver");
    assert_eq!(r.recovery.attempts, 0, "no fault was injected");
    assert!(r.bound.is_none());

    // Plain driver: no checkpoint sink, no supervisor.
    let without = min_secs(7, || surrogate::run(&o, &ranges, HubThreshold::Auto).unwrap().triangles);

    // Supervised: checkpoint store installed, progress acked per range,
    // exactly as `tricount count --on-fault recover` runs it.
    let with = min_secs(7, || {
        supervise(&job, &Fabric::Channel, p, FaultPolicy::Recover).unwrap().count
    });

    assert!(
        with <= without * 1.03,
        "fault-free supervision costs {:.2}% (budget 3%): \
         {with:.6}s supervised vs {without:.6}s plain",
        (with / without - 1.0) * 100.0
    );
}
