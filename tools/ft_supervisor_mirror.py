#!/usr/bin/env python3
"""Differential mirror of rust/src/ft/ (checkpoint + supervisor)
(authoring-container validation: the image has no Rust toolchain, so the
recovery math is proven out here before tier-1 runs post-merge).

Mirrors the design of DESIGN.md §13: a CheckpointStore holding acked
progress units (exact sums) + per-rank monotone partials; an explicit
survivor RankMap (no contiguous-id assumption — rank 0 can die); a
supervisor that salvages `acked_sum()` and re-counts only
`complement(n)` on the survivors; a degrade policy answering
`floor ≤ T ≤ acked + Σ C(d̂_v, 2)` from checkpoints; and the transport
retry protocol (deadline + bounded deterministic backoff) that survives
message drops without tripping the deadlock guard.

Validated properties (each a design-level acceptance criterion):
  1. salvage + recount(complement) == oracle on every kill position ×
     P ∈ {2,4,8} × seed (min-≺-vertex attribution: acked units count
     exactly the triangles whose minimum vertex lies in the unit);
  2. the degraded bound contains the truth on every cell, and the
     estimate lies inside the bound;
  3. replay determinism: same seed ⇒ identical acked set, identical
     recovered count, identical fault-schedule hash;
  4. killing rank 0 (the §V coordinator) recovers exactly through the
     explicit RankMap (new_of(0) is None, survivors re-indexed);
  5. a dropped message is survived by bounded retries (retries > 0,
     no guard trip); retry exhaustion against a dead peer attributes
     the failure to that peer;
  6. complement/remainder tiling: tasks tile the complement exactly,
     no overlap, no gap.

With --bench OUT.json, additionally derives BENCH_recovery.json on
PA(100k, 64): recovery latency (mirror wall seconds) and re-executed
work fraction vs kill position (first / middle / last transport op of
the victim) for a §V-style task run at P=8, each cell verified exact
against the fault-free oracle. Regenerate natively with
`cargo run --release -- bench-recovery`.

Run: python3 tools/ft_supervisor_mirror.py [--bench OUT.json]
"""

import json
import random
import sys
import time

FNV_OFFSET = 0xCBF29CE484222325
FNV_PRIME = 0x100000001B3
MASK = (1 << 64) - 1


def fnv_fold(h, x):
    for _ in range(8):
        h = ((h ^ (x & 0xFF)) * FNV_PRIME) & MASK
        x >>= 8
    return h


def combine_hashes(hs):
    h = FNV_OFFSET
    for x in hs:
        h = fnv_fold(h, x)
    return h


# ---------------------------------------------------------------------------
# CheckpointStore + RankMap (mirror of ft/checkpoint.rs)
# ---------------------------------------------------------------------------

class CheckpointStore:
    def __init__(self):
        self.units = {}  # (kind, lo, hi) -> [acked_or_None, {rank: partial}]

    def partial(self, rank, unit, s):
        self.units.setdefault(unit, [None, {}])[1][rank] = s

    def ack(self, rank, unit, s):
        self.units.setdefault(unit, [None, {}])[0] = s

    def acked_sum(self):
        return sum(a for a, _ in self.units.values() if a is not None)

    def floor_sum(self):
        return sum(a if a is not None else sum(p.values())
                   for a, p in self.units.values())

    def acked_ranges(self):
        spans = sorted((u[1], u[2]) for u, (a, _) in self.units.items()
                       if u[0] <= 1 and a is not None and u[2] > u[1])
        merged = []
        for lo, hi in spans:
            if merged and lo <= merged[-1][1]:
                merged[-1][1] = max(merged[-1][1], hi)
            else:
                merged.append([lo, hi])
        return [(lo, hi) for lo, hi in merged]

    def complement(self, n):
        out, at = [], 0
        for lo, hi in self.acked_ranges():
            if lo > at:
                out.append((at, min(lo, n)))
            at = max(at, hi)
            if at >= n:
                break
        if at < n:
            out.append((at, n))
        return out

    def unit_counts(self):
        acked = sum(1 for a, _ in self.units.values() if a is not None)
        return acked, len(self.units) - acked


class RankMap:
    def __init__(self, p, dead):
        self.survivors = [r for r in range(p) if r not in dead]

    def old_of(self, new):
        return self.survivors[new]

    def new_of(self, old):
        return self.survivors.index(old) if old in self.survivors else None


def remainder_tasks(rem, workers):
    """Mirror of supervisor::remainder_tasks: tile each complement
    interval in chunks of max(len // (2*workers), 1)."""
    tasks = []
    for lo, hi in rem:
        chunk = max((hi - lo) // (2 * max(workers, 1)), 1)
        at = lo
        while at < hi:
            ln = min(chunk, hi - at)
            tasks.append((at, at + ln))
            at += ln
    return tasks


# ---------------------------------------------------------------------------
# Graph: PA generator + degree-ordered orientation (relabelled so vertex
# id order == the ≺ total order, as the Rust preprocessing guarantees)
# ---------------------------------------------------------------------------

def pa_graph(n, d, seed):
    """Preferential attachment, d/2 edges per arriving node (pa:N:D)."""
    rng = random.Random(seed)
    half = d // 2
    endpoints = []
    adj = [set() for _ in range(n)]
    for v in range(n):
        if v == 0:
            continue
        for _ in range(min(half, v)):
            for _ in range(8):  # rejection: simple graph
                u = endpoints[rng.randrange(len(endpoints))] if endpoints \
                    else rng.randrange(v)
                if u != v and u not in adj[v]:
                    break
            else:
                continue
            adj[v].add(u)
            adj[u].add(v)
            endpoints.append(u)
            endpoints.append(v)
    return adj


def orient(adj):
    """Degree-order the vertices, relabel, and keep out-neighbors only
    (u → v iff u ≺ v). Returns sorted out-sets in relabelled ids."""
    n = len(adj)
    order = sorted(range(n), key=lambda v: (len(adj[v]), v))
    new_id = [0] * n
    for i, v in enumerate(order):
        new_id[v] = i
    out = [set() for _ in range(n)]
    for v in range(n):
        nv = new_id[v]
        for u in adj[v]:
            nu = new_id[u]
            if nv < nu:
                out[nv].add(nu)
    return out


def count_range(out, lo, hi):
    """Triangles whose minimum-≺ vertex lies in [lo, hi)."""
    t = 0
    for v in range(lo, hi):
        ov = out[v]
        for u in ov:
            t += len(ov & out[u])
    return t


def work_range(out, lo, hi):
    """Intersection work model: min(|out v|, |out u|) per oriented edge."""
    w = 0
    for v in range(lo, hi):
        lv = len(out[v])
        for u in out[v]:
            w += min(lv, len(out[u]))
    return w


def upper_bound_range(out, lo, hi):
    """Σ C(d̂_v, 2): max triangles closable at min-vertex v."""
    return sum(len(out[v]) * (len(out[v]) - 1) // 2 for v in range(lo, hi))


# ---------------------------------------------------------------------------
# Supervised §V-style run: a coordinator hands range tasks to P-1
# workers; workers ack each task with its exact sum. A kill fires at the
# victim's at_op-th transport op (1 op per task round-trip). Acked =
# tasks completed (by anyone) strictly before the kill's virtual time.
# ---------------------------------------------------------------------------

def task_stats(out, tasks):
    """Per-task (work, count), computed once — the scheduler and the ack
    bookkeeping reuse these instead of re-counting the graph."""
    return ([work_range(out, lo, hi) for lo, hi in tasks],
            [count_range(out, lo, hi) for lo, hi in tasks])


def simulate_tasked_run(tasks, tw, tc, p, seed, kill=None):
    """Greedy virtual-time schedule (deterministic in seed only through
    task order shuffling). Returns (store, victim_ops, schedule_hash,
    kill_time)."""
    rng = random.Random(seed)
    order = list(range(len(tasks)))
    rng.shuffle(order)
    workers = list(range(1, p))
    busy_until = {w: 0 for w in workers}
    ops = {w: 0 for w in workers}
    store = CheckpointStore()
    events = []
    kill_rank, kill_at = kill if kill else (None, None)
    kill_time = None
    done = []  # (finish_vt, worker, task_index)
    for ti in order:
        w = min(workers, key=lambda x: (busy_until[x], x))
        ops[w] += 1
        start = busy_until[w]
        if w == kill_rank and ops[w] == kill_at and kill_time is None:
            kill_time = start
            events.append((2, w, ops[w], start))
            continue  # the victim never completes this task
        busy_until[w] = start + tw[ti] + 1
        done.append((busy_until[w], w, ti))
        events.append((1, w, ti, busy_until[w]))
    for fin, w, ti in done:
        if kill_time is None or fin < kill_time:
            lo, hi = tasks[ti]
            store.ack(w, (1, lo, hi), tc[ti])
    h = combine_hashes(x for ev in events for x in ev)
    return store, ops, h, kill_time


def recover(out, n, store, p, dead):
    """Mirror of supervisor::recover for the salvage+complement paths."""
    m = RankMap(p, dead)
    if not m.survivors:
        raise RuntimeError("recovery impossible: all ranks died")
    salvage = store.acked_sum()
    rem = store.complement(n)
    tasks = remainder_tasks(rem, max(len(m.survivors) - 1, 1))
    reexec_work = sum(work_range(out, lo, hi) for lo, hi in tasks)
    recount = sum(count_range(out, lo, hi) for lo, hi in tasks)
    return salvage + recount, reexec_work, m, tasks


def degrade_bound(out, n, store):
    lower = store.floor_sum()
    upper = store.acked_sum() + sum(
        upper_bound_range(out, lo, hi) for lo, hi in store.complement(n))
    upper = max(upper, lower)
    covered = sum(work_range(out, lo, hi) for lo, hi in store.acked_ranges())
    total = work_range(out, 0, n)
    if covered > 0 and total > 0:
        est = round(lower * total / covered)
        est = min(max(est, lower), upper)
    else:
        est = lower + (upper - lower) // 2
    return lower, est, upper


# ---------------------------------------------------------------------------
# Retry protocol mirror (recv_deadline + bounded deterministic backoff)
# ---------------------------------------------------------------------------

def retry_protocol(drop_first_n, peer_dead=False, max_retries=3):
    """A requester resending through recv_retry: the channel drops the
    first `drop_first_n` replies. Returns (ok, retries, guard_trips)."""
    retries = 0
    delivered = 0
    for attempt in range(max_retries + 1):
        if peer_dead:
            return ("dead-peer", retries, 0)
        delivered += 1
        if delivered > drop_first_n:
            return ("ok", retries, 0)
        # deadline expires in virtual time (no deadlock-guard trip),
        # bounded backoff, deterministic resend
        if attempt < max_retries:
            retries += 1
    return ("exhausted", retries, 0)


# ---------------------------------------------------------------------------
# Validation battery
# ---------------------------------------------------------------------------

def main():
    failures = []

    def check(name, cond, detail=""):
        tag = "ok" if cond else "FAIL"
        print(f"  [{tag}] {name}" + (f" — {detail}" if detail and not cond else ""))
        if not cond:
            failures.append(name)

    print("ft supervisor mirror: validation battery")
    adj = pa_graph(2000, 16, seed=7)
    out = orient(adj)
    n = len(out)
    oracle = count_range(out, 0, n)
    total_work = work_range(out, 0, n)
    print(f"  graph: PA(2000,16) n={n} oracle={oracle} work={total_work}")

    # 1+2+3: kill matrix, recovery exactness + degrade containment + replay
    for p in (2, 4, 8):
        base_tasks = remainder_tasks([(0, n)], max(p - 1, 1))
        tw, tc = task_stats(out, base_tasks)
        for seed in range(4):
            probe_store, probe_ops, _, _ = simulate_tasked_run(
                base_tasks, tw, tc, p, seed)
            assert probe_store.acked_sum() == oracle
            victim = 1 if p > 1 else 0
            v_ops = probe_ops.get(victim, 1)
            for pos, at_op in (("first", 1), ("middle", max(v_ops // 2, 1)),
                               ("last", max(v_ops, 1))):
                st, _, h1, kt = simulate_tasked_run(
                    base_tasks, tw, tc, p, seed, kill=(victim, at_op))
                st2, _, h2, _ = simulate_tasked_run(
                    base_tasks, tw, tc, p, seed, kill=(victim, at_op))
                got, reexec, m, _ = recover(out, n, st, p, {victim})
                got2, _, _, _ = recover(out, n, st2, p, {victim})
                lab = f"P={p} seed={seed} {pos}"
                check(f"recover exact {lab}", got == oracle,
                      f"{got} != {oracle}")
                check(f"replay identical {lab}",
                      h1 == h2 and got == got2)
                lo, est, hi = degrade_bound(out, n, st)
                check(f"degrade bound contains truth {lab}",
                      lo <= oracle <= hi, f"{lo}..{hi} vs {oracle}")
                check(f"estimate inside bound {lab}", lo <= est <= hi)

    # 4: rank 0 (coordinator) dies — explicit RankMap, no contiguity
    m = RankMap(4, {0})
    check("rank-0 death: survivors re-indexed",
          m.survivors == [1, 2, 3] and m.new_of(0) is None
          and m.old_of(0) == 1 and m.new_of(3) == 2)
    tasks4 = remainder_tasks([(0, n)], 3)
    tw4, tc4 = task_stats(out, tasks4)
    st, _, _, _ = simulate_tasked_run(tasks4, tw4, tc4, 4, 1, kill=(1, 1))
    got, _, _, _ = recover(out, n, st, 4, {0, 1})
    check("recovery with ranks {0,1} dead is exact", got == oracle)

    # 5: drop-retry protocol
    ok, retries, guards = retry_protocol(drop_first_n=2)
    check("dropped msgs survived by bounded retries",
          ok == "ok" and retries == 2 and guards == 0)
    ok, retries, _ = retry_protocol(drop_first_n=99)
    check("retry exhaustion is bounded",
          ok == "exhausted" and retries == 3)
    ok, _, _ = retry_protocol(drop_first_n=0, peer_dead=True)
    check("dead peer attributed, not retried forever", ok == "dead-peer")

    # 6: remainder tiling
    for rem in ([(0, 100)], [(3, 17), (40, 41), (90, 100)], []):
        tasks = remainder_tasks(rem, 3)
        flat = sorted(tasks)
        tiles = all(flat[i][1] == flat[i + 1][0] or
                    flat[i][1] <= flat[i + 1][0] for i in range(len(flat) - 1))
        covered = sum(hi - lo for lo, hi in tasks)
        want = sum(hi - lo for lo, hi in rem)
        check(f"tasks tile {rem}", tiles and covered == want)

    # checkpoint-store unit semantics
    s = CheckpointStore()
    s.ack(1, (0, 0, 10), 100)
    s.partial(2, (0, 15, 20), 3)
    s.partial(2, (0, 15, 20), 9)  # monotone overwrite
    check("floor = acked + latest partials",
          s.acked_sum() == 100 and s.floor_sum() == 109)
    check("complement skips acked coverage",
          s.complement(30) == [(10, 30)])

    if failures:
        print(f"MIRROR FAILURES: {failures}")
        return 1
    print("  all checks passed")

    if "--bench" in sys.argv:
        out_path = sys.argv[sys.argv.index("--bench") + 1]
        bench(out_path)
    return 0


# ---------------------------------------------------------------------------
# BENCH_recovery.json derivation on PA(100k, 64), P=8
# ---------------------------------------------------------------------------

def bench(out_path):
    print("bench: PA(100000,64) P=8 victim=1 (mirror-derived)")
    t0 = time.time()
    adj = pa_graph(100_000, 64, seed=42)
    out = orient(adj)
    n = len(out)
    m = sum(len(o) for o in out)
    print(f"  built n={n} m={m} in {time.time()-t0:.1f}s")
    p = 8
    # The §V balancer's shrinking granularity issues many small tasks;
    # tile ~16 per worker so the kill-position axis is well resolved.
    tasks = remainder_tasks([(0, n)], (p - 1) * 8)

    t0 = time.time()
    oracle = count_range(out, 0, n)
    base_wall = time.time() - t0
    tw, tc = task_stats(out, tasks)
    base_work = sum(tw)
    assert sum(tc) == oracle
    probe_store, probe_ops, _, _ = simulate_tasked_run(tasks, tw, tc, p, 42)
    assert probe_store.acked_sum() == oracle
    victim = 1
    v_ops = probe_ops[victim]
    print(f"  oracle={oracle} base_wall={base_wall:.3f}s "
          f"work={base_work} victim_ops={v_ops}")

    rows = [{
        "position": "baseline", "victim": "-", "at_op": 0, "attempts": 0,
        "triangles": oracle, "exact": "true",
        "wall_s": round(base_wall, 6), "reexec_work_frac": 0.0,
        "reexec_bytes": 0, "salvaged_units": 0,
    }]
    for pos, at_op in (("first", 1), ("middle", max(v_ops // 2, 1)),
                       ("last", max(v_ops, 1))):
        st, _, _, _ = simulate_tasked_run(tasks, tw, tc, p, 42,
                                          kill=(victim, at_op))
        salvaged, _ = st.unit_counts()
        t0 = time.time()
        got, reexec_work, _, rtasks = recover(out, n, st, p, {victim})
        wall = time.time() - t0
        exact = got == oracle
        frac = reexec_work / max(base_work, 1)
        # assign(16 B) + result(12 B) per re-executed task, the §V wire cost
        reexec_bytes = 28 * len(rtasks)
        print(f"  {pos:>7} (op {at_op}): triangles={got} exact={exact} "
              f"wall={wall:.3f}s frac={frac:.4f} salvaged={salvaged}")
        rows.append({
            "position": pos, "victim": victim, "at_op": at_op, "attempts": 1,
            "triangles": got, "exact": str(exact).lower(),
            "wall_s": round(wall, 6),
            "reexec_work_frac": round(frac, 6),
            "reexec_bytes": reexec_bytes, "salvaged_units": salvaged,
        })
        if not exact:
            raise SystemExit(f"bench: {pos} recovery not exact")

    doc = {
        "columns": ["position", "victim", "at_op", "attempts", "triangles",
                    "exact", "wall_s", "reexec_work_frac", "reexec_bytes",
                    "salvaged_units"],
        "rows": rows,
        "notes": [
            "workload pa:100000:64, P=8, victim rank 1 (a worker; rank 0 "
            "coordinates), dynamic-lb-style task run; kill position = the "
            "victim's first / middle / last transport op; every recovered "
            "count verified equal to the fault-free oracle",
            f"victim's fault-free transport-op budget: {v_ops}; "
            f"reexec_work_frac = recovery intersection work / fault-free "
            f"counting work ({base_work} units)",
            "harness: tools/ft_supervisor_mirror.py --bench — a Python "
            "mirror of ft/supervisor.rs salvage + complement recovery (the "
            "authoring container ships no Rust toolchain; wall_s are mirror "
            "wall seconds and only the relative trend is meaningful); "
            "regenerate natively with `cargo run --release -- "
            "bench-recovery --workload pa:100000:64 --procs 8`, which "
            "emits this same schema",
            "the monotone trend is the checkpoint contract made "
            "quantitative: later kills leave more acked task units behind, "
            "so recovery re-executes a smaller complement",
        ],
    }
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    print(f"  [written: {out_path}]")


if __name__ == "__main__":
    sys.exit(main())
