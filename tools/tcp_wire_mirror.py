#!/usr/bin/env python3
"""Toolchain-free mirror of the `comm/tcp` wire protocol (DESIGN.md §15).

The authoring container has no Rust toolchain, so this script re-implements
the socket fabric's protocol state machines — frame codec, rank-0
rendezvous, lower-dials/higher-accepts mesh, rank-0-coordinated collectives,
end-of-run result allgather — in Python over real loopback sockets, and
drives the same scenarios the Rust test suite asserts:

  1. codec totality: every truncation point of a valid frame/hello fails
     deterministically; oversize length prefixes are rejected before
     allocation.
  2. live mesh: a P-rank toy protocol where every rank messages every peer,
     barriers, reduces, and allgathers results — asserting identical
     gathered vectors on all ranks, per-(src,dst) non-overtaking sequence
     numbers, sent==received conservation per tag class, and
     wire_overhead == FRAME_HEADER_BYTES * frames.
  3. rendezvous failures: duplicate rank, missing rank (join timeout), and
     job-id mismatch each produce a deterministic host error while every
     joined worker unblocks (reject byte or EOF) — no hangs.

Run: python3 tools/tcp_wire_mirror.py
"""

import io
import socket
import struct
import threading
import time

MAGIC = 0x54524943  # "TRIC" little-endian
WIRE_VERSION = 1
HELLO_BYTES = 24
FRAME_HEADER_BYTES = 20
MAX_FRAME_BYTES = 1 << 30

TAG_MSG, TAG_BARRIER, TAG_BARRIER_GO, TAG_REDUCE, TAG_REDUCE_GO, \
    TAG_RETIRE, TAG_RESULT, TAG_RESULT_GO = range(8)


class Comm(Exception):
    pass


class Config(Exception):
    pass


# ---------------------------------------------------------------------------
# Codec (mirrors encode_frame / read_frame / encode_hello / read_hello)
# ---------------------------------------------------------------------------

def encode_frame(src, dst, tag, control, payload):
    return struct.pack("<5I", src, dst, tag, control, len(payload)) + payload


def read_exact(r, n, what):
    buf = b""
    while len(buf) < n:
        chunk = r.read(n - len(buf)) if hasattr(r, "read") else r.recv(n - len(buf))
        if not chunk:
            raise Comm(f"mid-stream disconnect while reading {what}: "
                       f"got {len(buf)} of {n} bytes")
        buf += chunk
    return buf


def read_frame(r):
    """Ok(None) analogue: returns None on clean EOF at a frame boundary."""
    first = r.read(1) if hasattr(r, "read") else r.recv(1)
    if not first:
        return None
    hdr = first + read_exact(r, FRAME_HEADER_BYTES - 1, "frame header")
    src, dst, tag, control, ln = struct.unpack("<5I", hdr)
    if ln > MAX_FRAME_BYTES:
        raise Comm(f"frame length {ln} exceeds the {MAX_FRAME_BYTES}-byte cap")
    return src, dst, tag, control, read_exact(r, ln, "frame payload")


def encode_hello(job_id, rank, procs):
    return struct.pack("<IIQII", MAGIC, WIRE_VERSION, job_id, rank, procs)


def read_hello(r):
    b = read_exact(r, HELLO_BYTES, "hello")
    magic, version, job_id, rank, procs = struct.unpack("<IIQII", b)
    if magic != MAGIC:
        raise Config(f"bad rendezvous magic {magic:#010x} — not a tricount peer")
    if version != WIRE_VERSION:
        raise Config(f"wire version mismatch: peer speaks v{version}")
    return job_id, rank, procs


def scenario_codec_totality():
    frame = encode_frame(3, 1, TAG_RESULT, 42, bytes(range(9)))
    got = read_frame(io.BytesIO(frame))
    assert got == (3, 1, TAG_RESULT, 42, bytes(range(9)))
    assert read_frame(io.BytesIO(b"")) is None
    for cut in range(1, len(frame)):
        try:
            read_frame(io.BytesIO(frame[:cut]))
            raise AssertionError(f"cut {cut} decoded")
        except Comm:
            pass
    big = struct.pack("<5I", 0, 1, 0, 0, MAX_FRAME_BYTES + 1)
    try:
        read_frame(io.BytesIO(big))
        raise AssertionError("oversize accepted")
    except Comm as e:
        assert "exceeds" in str(e)
    hello = encode_hello(0xDEADBEEF, 2, 8)
    assert read_hello(io.BytesIO(hello)) == (0xDEADBEEF, 2, 8)
    try:
        read_hello(io.BytesIO(b"\xff" + hello[1:]))
        raise AssertionError("bad magic accepted")
    except Config:
        pass
    print("ok  codec totality (truncation sweep, oversize cap, hello validation)")


# ---------------------------------------------------------------------------
# Rendezvous + mesh (mirrors host_rendezvous / worker join / establish)
# ---------------------------------------------------------------------------

def write_blob(sock, b):
    sock.sendall(struct.pack("<Q", len(b)) + b)


def read_blob(sock):
    (n,) = struct.unpack("<Q", read_exact(sock, 8, "blob length"))
    return read_exact(sock, n, "blob")


def host_rendezvous(listener, procs, job_id, timeout):
    """Rank 0: accept hellos, validate the roster, broadcast the peer table.

    Returns (streams, mesh_addrs). On any roster error every accepted
    socket is closed (joined workers unblock via reject byte or EOF)."""
    listener.settimeout(0.05)
    joined = {}   # rank -> (sock, mesh_addr)
    deadline = time.monotonic() + timeout
    try:
        while len(joined) < procs - 1:
            if time.monotonic() >= deadline:
                missing = sorted(set(range(1, procs)) - set(joined))
                raise Config("rendezvous join timeout: missing rank(s) "
                             + ",".join(map(str, missing)))
            try:
                s, _ = listener.accept()
                s.settimeout(None)
            except socket.timeout:
                continue
            jid, rank, p = read_hello(s)
            mesh_addr = read_blob(s).decode()
            if jid != job_id:
                raise Config(f"rendezvous job-id mismatch: worker presented {jid:#x}")
            if p != procs:
                raise Config(f"rendezvous procs mismatch: worker built for P={p}")
            if rank == 0 or rank >= procs:
                raise Config(f"out-of-range rank {rank} at rendezvous")
            if rank in joined:
                raise Config(f"duplicate rank {rank} at rendezvous")
            joined[rank] = (s, mesh_addr)
    except Exception as e:
        reason = str(e).encode()
        for s, _ in joined.values():
            try:
                s.sendall(b"\x01")
                write_blob(s, reason)
            except OSError:
                pass
            s.close()  # un-notified workers unblock via EOF
        raise
    table = ["host"] + [joined[r][1] for r in range(1, procs)]
    enc = struct.pack("<Q", len(table)) + b"".join(
        struct.pack("<Q", len(a)) + a.encode() for a in table)
    for r in range(1, procs):
        s = joined[r][0]
        s.sendall(b"\x00")
        write_blob(s, enc)
    return {r: joined[r][0] for r in range(1, procs)}


def worker_join(connect, rank, procs, job_id, timeout):
    deadline = time.monotonic() + timeout
    while True:
        try:
            s0 = socket.create_connection(connect, timeout=0.25)
            s0.settimeout(None)
            break
        except OSError as e:
            if time.monotonic() >= deadline:
                raise Config(f"rank {rank}: could not reach rendezvous: {e}")
            time.sleep(0.025)
    mesh = socket.socket()
    mesh.bind(("127.0.0.1", 0))
    mesh.listen(procs)
    s0.sendall(encode_hello(job_id, rank, procs))
    write_blob(s0, f"127.0.0.1:{mesh.getsockname()[1]}".encode())
    status = read_exact(s0, 1, "rendezvous status")
    if status == b"\x01":
        raise Config(f"rank {rank}: rendezvous rejected this worker: "
                     + read_blob(s0).decode())
    table_raw = read_blob(s0)
    (n,) = struct.unpack("<Q", table_raw[:8])
    table, at = [], 8
    for _ in range(n):
        (ln,) = struct.unpack("<Q", table_raw[at:at + 8])
        table.append(table_raw[at + 8:at + 8 + ln].decode())
        at += 8 + ln
    streams = {0: s0}
    for i in range(1, rank):          # dial every lower-ranked worker
        host, port = table[i].rsplit(":", 1)
        s = socket.create_connection((host, int(port)))
        s.sendall(encode_hello(job_id, rank, procs))
        streams[i] = s
    mesh.settimeout(0.05)
    while len(streams) < procs - 1:   # one stream per peer (all but self)
        if time.monotonic() >= deadline:
            raise Comm(f"rank {rank}: mesh join timeout")
        try:
            s, _ = mesh.accept()       # accept from every higher-ranked peer
            s.settimeout(None)
        except socket.timeout:
            continue
        jid, j, p = read_hello(s)
        if jid != job_id or p != procs or j <= rank or j in streams:
            raise Comm(f"rank {rank}: unexpected mesh hello from rank {j}")
        streams[j] = s
    mesh.close()
    return streams


# ---------------------------------------------------------------------------
# Transport (per-stream reader threads + rank-0-coordinated collectives)
# ---------------------------------------------------------------------------

class Rank:
    def __init__(self, rank, procs, streams):
        self.rank, self.procs, self.streams = rank, procs, streams
        self.lock = threading.Lock()
        self.got = threading.Condition(self.lock)
        self.inbox = {t: [] for t in range(8)}
        self.sent = self.received = self.bytes_sent = self.overhead = 0
        self.frames_sent = 0
        self.last_seq = {}            # src -> last control value seen
        self.readers = [threading.Thread(target=self._pump, args=(p,), daemon=True)
                        for p in streams]
        for t in self.readers:
            t.start()

    def _pump(self, peer):
        while True:
            try:
                f = read_frame(self.streams[peer])
            except (Comm, OSError):
                return
            if f is None:
                return
            src, _dst, tag, control, payload = f
            with self.got:
                if tag == TAG_MSG:
                    # non-overtaking: one ordered TCP stream per directed
                    # edge ⇒ per-source sequence numbers arrive monotone.
                    assert control == self.last_seq.get(src, -1) + 1, \
                        f"rank {self.rank}: overtaking from {src}"
                    self.last_seq[src] = control
                    self.received += 1
                self.inbox[tag].append((src, control, payload))
                self.got.notify_all()

    def _send_raw(self, dst, tag, control, payload):
        frame = encode_frame(self.rank, dst, tag, control, payload)
        self.streams[dst].sendall(frame)
        self.frames_sent += 1
        self.overhead += FRAME_HEADER_BYTES

    def send(self, dst, seq, payload):
        self._send_raw(dst, TAG_MSG, seq, payload)
        self.sent += 1
        self.bytes_sent += len(payload)

    def _wait(self, tag, n=1):
        with self.got:
            while len(self.inbox[tag]) < n:
                assert self.got.wait(timeout=10), f"rank {self.rank}: hang on tag {tag}"
            out, self.inbox[tag] = self.inbox[tag][:n], self.inbox[tag][n:]
            return out

    def barrier(self, epoch):
        if self.rank == 0:
            self._wait(TAG_BARRIER, self.procs - 1)
            for d in range(1, self.procs):
                self._send_raw(d, TAG_BARRIER_GO, epoch, b"")
        else:
            self._send_raw(0, TAG_BARRIER, epoch, b"")
            self._wait(TAG_BARRIER_GO)

    def reduce_sum(self, value, epoch):
        if self.rank == 0:
            parts = self._wait(TAG_REDUCE, self.procs - 1)
            total = value + sum(struct.unpack("<Q", p)[0] for _, _, p in parts)
            for d in range(1, self.procs):
                self._send_raw(d, TAG_REDUCE_GO, epoch, struct.pack("<Q", total))
            return total
        self._send_raw(0, TAG_REDUCE, epoch, struct.pack("<Q", value))
        return struct.unpack("<Q", self._wait(TAG_REDUCE_GO)[0][2])[0]

    def allgather_result(self, blob):
        if self.rank == 0:
            parts = {0: blob}
            for src, _, p in self._wait(TAG_RESULT, self.procs - 1):
                assert src not in parts, f"duplicate result from rank {src}"
                parts[src] = p
            joined = b"".join(struct.pack("<Q", len(parts[r])) + parts[r]
                              for r in range(self.procs))
            for d in range(1, self.procs):
                self._send_raw(d, TAG_RESULT_GO, 0, joined)
        else:
            self._send_raw(0, TAG_RESULT, 0, blob)
            joined = self._wait(TAG_RESULT_GO)[0][2]
        out, at = [], 0
        while at < len(joined):
            (ln,) = struct.unpack("<Q", joined[at:at + 8])
            out.append(joined[at + 8:at + 8 + ln])
            at += 8 + ln
        return out


def run_rank(rank, procs, job_id, listener, connect, results, errors):
    try:
        if rank == 0:
            peers = host_rendezvous(listener, procs, job_id, 10)
        else:
            peers = worker_join(connect, rank, procs, job_id, 10)
        node = Rank(rank, procs, peers)
        # Toy protocol: (rank+1)*(dst+2) messages to every peer, sequenced.
        for dst in range(procs):
            if dst == rank:
                continue
            for seq in range((rank + 1) * (dst + 2)):
                node.send(dst, seq, bytes([rank]) * (seq % 5))
        expect = sum((src + 1) * (rank + 2) for src in range(procs) if src != rank)
        deadline = time.monotonic() + 10
        while node.received < expect:
            assert time.monotonic() < deadline, f"rank {rank}: recv hang"
            time.sleep(0.001)
        node.barrier(epoch=1)
        total = node.reduce_sum(rank * 100, epoch=2)
        blob = struct.pack("<QQQQQ", node.sent, node.received,
                           node.bytes_sent, node.frames_sent, node.overhead)
        gathered = node.allgather_result(blob)
        results[rank] = (total, gathered)
    except Exception as e:  # noqa: BLE001 — collected and asserted by main
        errors[rank] = e


def scenario_live_mesh(procs=4):
    listener = socket.socket()
    listener.bind(("127.0.0.1", 0))
    listener.listen(procs)
    connect = listener.getsockname()
    results, errors = {}, {}
    threads = [threading.Thread(target=run_rank,
                                args=(r, procs, 7, listener, connect, results, errors))
               for r in range(procs)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
        assert not t.is_alive(), "rank thread hung"
    assert not errors, errors
    base_total, base_gather = results[0]
    assert base_total == sum(r * 100 for r in range(procs))
    per_rank = [struct.unpack("<QQQQQ", b) for b in base_gather]
    for r in range(procs):
        # identical allgathered vector on every rank
        assert results[r] == (base_total, base_gather), f"rank {r} result differs"
    sent = sum(m[0] for m in per_rank)
    received = sum(m[1] for m in per_rank)
    assert sent == received, f"conservation: {sent} != {received}"
    for r, m in enumerate(per_rank):
        assert m[4] == FRAME_HEADER_BYTES * m[3], f"rank {r}: overhead mismatch"
        assert m[4] > 0
    print(f"ok  live mesh P={procs} (identical allgather, non-overtaking, "
          f"Σsent={sent}==Σreceived, overhead==20*frames)")


# ---------------------------------------------------------------------------
# Rendezvous failures
# ---------------------------------------------------------------------------

def scenario_rendezvous_failures():
    def host(procs, job_id, timeout=2.0):
        listener = socket.socket()
        listener.bind(("127.0.0.1", 0))
        listener.listen(procs)
        return listener, listener.getsockname()

    def join(connect, rank, procs, job_id, errs):
        try:
            worker_join(connect, rank, procs, job_id, 8)
        except Exception as e:  # noqa: BLE001
            errs[rank] = e

    # duplicate rank: host rejects, both joined workers unblock with errors
    listener, connect = host(3, 7)
    errs = {}
    ts = [threading.Thread(target=join, args=(connect, 1, 3, 7, errs)),
          threading.Thread(target=join, args=(connect, 1, 3, 7, errs))]
    for t in ts:
        t.start()
    try:
        host_rendezvous(listener, 3, 7, 5)
        raise AssertionError("duplicate rank accepted")
    except Config as e:
        assert "duplicate rank 1" in str(e), e
    for t in ts:
        t.join(timeout=10)
        assert not t.is_alive(), "worker hung after duplicate-rank reject"
    assert len(errs) >= 1  # same-rank threads race on one dict slot

    # missing rank: deterministic join timeout naming the absentee
    listener, connect = host(3, 7)
    errs = {}
    t = threading.Thread(target=join, args=(connect, 1, 3, 7, errs))
    t.start()
    t0 = time.monotonic()
    try:
        host_rendezvous(listener, 3, 7, 1.0)
        raise AssertionError("missing rank accepted")
    except Config as e:
        assert "missing rank(s) 2" in str(e), e
    assert time.monotonic() - t0 < 5
    t.join(timeout=10)
    assert not t.is_alive(), "worker hung after host timeout (EOF must unblock)"
    assert 1 in errs, "joined worker must observe the abort"

    # job-id mismatch: reject byte + reason reaches the stale worker
    listener, connect = host(2, 0xBBBB)
    errs = {}
    t = threading.Thread(target=join, args=(connect, 1, 2, 0xAAAA, errs))
    t.start()
    try:
        host_rendezvous(listener, 2, 0xBBBB, 5)
        raise AssertionError("job-id mismatch accepted")
    except Config as e:
        assert "job-id mismatch" in str(e), e
    t.join(timeout=10)
    assert not t.is_alive()
    assert "rejected" in str(errs[1]) or isinstance(errs[1], Comm), errs
    print("ok  rendezvous failures (duplicate rank, missing rank, job-id "
          "mismatch — deterministic errors, no hangs)")


if __name__ == "__main__":
    scenario_codec_totality()
    scenario_live_mesh(procs=2)
    scenario_live_mesh(procs=4)
    scenario_live_mesh(procs=8)
    scenario_rendezvous_failures()
    print("tcp wire mirror: all scenarios passed")
