#!/usr/bin/env python3
"""Differential mirror of rust/src/testkit/sim.rs (authoring-container
validation: the image has no Rust toolchain, so the scheduler state
machine is proven out here before tier-1 runs post-merge).

Mirrors the exact design: one execution token; rank programs as
coroutines yielding transport ops; scheduler choices (resume / deliver /
guard) drawn from a seeded RNG; virtual time advanced only by
deliveries; per-edge monotone delivery clocks; kill/drop/slow faults;
FNV-1a trace hashing over (step, kind, src, dst, tag, bytes, vt).

Validated properties (each a design-level acceptance criterion):
  1. same seed => identical trace hash and results (replay determinism);
  2. different seeds explore different schedules;
  3. per-(src,dst) FIFO under jitter (MPI non-overtaking);
  4. a surrogate-shaped protocol counts exactly on every schedule,
     including straggler ranks, and drains (sent == received);
  5. termination: kill/drop never hang -- blocked ranks fail through the
     deadlock guard deterministically;
  6. barrier/reduce generations complete or guard-fail, never wedge.

Run: python3 tools/testkit_sim_mirror.py
"""

import heapq
import itertools
import random
from collections import deque

FNV_OFFSET = 0xCBF29CE484222325
FNV_PRIME = 0x100000001B3
MASK = (1 << 64) - 1

SEND, DELIVER, DROP_FAULT, DROP_UNREACH, DEATH, GUARD, BARRIER, REDUCE = range(1, 9)

READY, RUNNING, BRECV, BBARRIER, BREDUCE, DONE, DEAD = range(7)


def fnv_fold(h, x):
    for _ in range(8):
        h = ((h ^ (x & 0xFF)) * FNV_PRIME) & MASK
        x >>= 8
    return h


class Trace:
    def __init__(self):
        self.hash = FNV_OFFSET
        self.events = self.sends = self.delivered = self.dropped = 0
        self.deaths = self.guards = 0

    def event(self, kind, src, dst, tag, nbytes, vt):
        self.events += 1
        h = self.hash
        for x in (self.events, kind, src, dst, tag, nbytes, vt):
            h = fnv_fold(h, x)
        self.hash = h
        if kind == SEND:
            self.sends += 1
        elif kind == DELIVER:
            self.delivered += 1
        elif kind in (DROP_FAULT, DROP_UNREACH):
            self.dropped += 1
        elif kind == DEATH:
            self.deaths += 1
        elif kind == GUARD:
            self.guards += 1


class Sim:
    """The SimState + scheduler, with rank programs as generators that
    yield op tuples and receive op results via .send()."""

    def __init__(self, p, programs, seed, jitter=24, switch=0.5, bias=0.35,
                 kills=(), drops=(), slow=()):
        self.p = p
        self.rng = random.Random(seed)
        self.jitter, self.switch, self.bias = jitter, switch, bias
        self.kills = dict(kills)          # rank -> at_op
        self.drops = set(drops)           # (src, dst, nth)
        self.slow = dict(slow)            # rank -> factor
        self.phase = [READY] * p
        self.mailbox = [deque() for _ in range(p)]
        self.ops = [0] * p
        self.result = [None] * p          # 'ok', or ('err', msg)
        self.recv_count = [0] * p
        self.in_flight = []               # heap of (at, seq, dst, env)
        self.seq = itertools.count(1)
        self.edge_clock = [0] * (p * p)
        self.edge_sends = [0] * (p * p)
        self.now = 0
        self.trace = Trace()
        self.bar_wait = 0
        self.red_cells = [None] * p
        self.red_result = 0
        # pending wake-value for ranks woken from a block
        self.wake = [None] * p
        self.progs = [programs[r](r) for r in range(p)]

    # -- scheduler (mirrors SimState::schedule) --------------------------
    def schedule(self):
        while True:
            ready = [i for i in range(self.p) if self.phase[i] == READY]
            can_deliver = bool(self.in_flight)
            deliver = can_deliver and (not ready or self.rng.random() < self.bias)
            if deliver:
                at, _, dst, env = heapq.heappop(self.in_flight)
                self.now = max(self.now, at)
                src, tag, nbytes, _ = env
                if self.phase[dst] in (DONE, DEAD):
                    self.trace.event(DROP_UNREACH, src, dst, tag, nbytes, self.now)
                else:
                    self.trace.event(DELIVER, src, dst, tag, nbytes, self.now)
                    self.mailbox[dst].append(env)
                    if self.phase[dst] == BRECV:
                        self.wake[dst] = ("msg", self.mailbox[dst].popleft())
                        self.phase[dst] = READY
                continue
            if ready:
                pick = ready[self.rng.randrange(len(ready))]
                self.phase[pick] = RUNNING
                return pick
            blocked = [i for i in range(self.p)
                       if self.phase[i] in (BRECV, BBARRIER, BREDUCE)]
            if not blocked:
                return None
            for i in blocked:
                self.trace.event(GUARD, i, 0, 0, 0, self.now)
                self.wake[i] = ("fail", f"rank {i} virtual recv guard at vt {self.now}")
                self.phase[i] = READY

    def _drain_dead(self, r, first):
        """Run a dead rank's program to completion (it keeps executing on
        its own thread in Rust, with every transport op failing fast)."""
        val = first
        while True:
            try:
                op = self.progs[r].send(val)
            except StopIteration as st:
                self.result[r] = st.value if st.value is not None else "ok"
                return
            if op[0] == "try_recv":
                val = ("none", None)
            elif op[0] == "send":
                val = ("err", f"rank {r} is dead")
            else:
                val = ("fail", f"rank {r} is dead")

    # -- op execution (mirrors the VirtualEndpoint ops) -------------------
    def run(self):
        feed = {}            # rank -> result to send into its generator
        pending_try = set()  # ranks mid-try_recv that yielded the token
        cur = self.schedule()
        while cur is not None:
            r = cur
            # complete an interrupted try_recv now that we hold the token
            if r in pending_try:
                pending_try.discard(r)
                feed[r] = (("msg", self.mailbox[r].popleft())
                           if self.mailbox[r] else ("none", None))
            # consume a wake value set by the scheduler (recv/collectives)
            if self.wake[r] is not None:
                feed[r] = self.wake[r]
                self.wake[r] = None
            try:
                op = self.progs[r].send(feed.pop(r, None))
            except StopIteration as st:
                self.result[r] = st.value if st.value is not None else "ok"
                if self.phase[r] != DEAD:
                    self.phase[r] = DONE
                cur = self.schedule()
                continue
            kind = op[0]
            # preamble: op count + kill fault (try_recv included; it cannot
            # fail, so a kill there is silent and the next fallible op errs)
            self.ops[r] += 1
            if (r in self.kills and self.ops[r] >= self.kills[r]
                    and self.phase[r] != DEAD):
                self.phase[r] = DEAD
                self.trace.event(DEATH, r, 0, self.ops[r], 0, self.now)
                if kind == "try_recv":
                    first = ("none", None)
                elif kind == "send":
                    first = ("err", f"rank {r} killed at op {self.ops[r]}")
                else:
                    first = ("fail", f"rank {r} killed at op {self.ops[r]}")
                self._drain_dead(r, first)
                cur = self.schedule()
                continue

            if kind == "send":
                _, dst, tag, nbytes, payload = op
                if self.phase[dst] in (DEAD, DONE):
                    feed[r] = ("err", f"rank {r} send to dead rank {dst}")
                    continue
                e = r * self.p + dst
                self.edge_sends[e] += 1
                self.trace.event(SEND, r, dst, tag, nbytes, self.now)
                if (r, dst, self.edge_sends[e]) in self.drops:
                    self.trace.event(DROP_FAULT, r, dst, tag, nbytes, self.now)
                else:
                    delay = 1 + (self.rng.randrange(self.jitter) if self.jitter else 0)
                    for who, f in self.slow.items():
                        if who in (r, dst):
                            delay *= f
                    at = max(self.now + delay, self.edge_clock[e] + 1)
                    self.edge_clock[e] = at
                    heapq.heappush(self.in_flight,
                                   (at, next(self.seq), dst, (r, tag, nbytes, payload)))
                feed[r] = ("ok", None)
                if self.rng.random() < self.switch:
                    self.phase[r] = READY
                    cur = self.schedule()
            elif kind == "try_recv":
                if self.rng.random() < self.switch:
                    self.phase[r] = READY
                    pending_try.add(r)
                    cur = self.schedule()
                else:
                    feed[r] = (("msg", self.mailbox[r].popleft())
                               if self.mailbox[r] else ("none", None))
            elif kind == "recv":
                if self.mailbox[r]:
                    feed[r] = ("msg", self.mailbox[r].popleft())
                else:
                    self.phase[r] = BRECV
                    cur = self.schedule()  # wake[r] will carry the result
            elif kind == "barrier":
                self.bar_wait += 1
                if self.bar_wait == self.p:
                    self.bar_wait = 0
                    self.trace.event(BARRIER, r, 0, 0, 0, self.now)
                    for i in range(self.p):
                        if self.phase[i] == BBARRIER:
                            self.wake[i] = ("ok", None)
                            self.phase[i] = READY
                    self.wake[r] = ("ok", None)
                    self.phase[r] = READY
                else:
                    self.phase[r] = BBARRIER
                cur = self.schedule()
            elif kind == "reduce":
                self.red_cells[r] = op[1]
                if all(c is not None for c in self.red_cells):
                    s = sum(self.red_cells)
                    self.red_result = s
                    self.red_cells = [None] * self.p
                    self.trace.event(REDUCE, r, 0, 0, s, self.now)
                    for i in range(self.p):
                        if self.phase[i] == BREDUCE:
                            self.wake[i] = ("red", s)
                            self.phase[i] = READY
                    self.wake[r] = ("red", s)
                    self.phase[r] = READY
                else:
                    self.phase[r] = BREDUCE
                cur = self.schedule()
            else:
                raise AssertionError(f"unknown op {kind}")
        return self


# ---------------------------------------------------------------------------
# protocol programs (generators): yield op tuples, receive ('ok'|'msg'|...)


def ring_program(total):
    def prog(r):
        res = yield ("send", (r + 1) % total, 0, 8, r * r)
        if res[0] == "err":
            return ("err", res[1])
        res = yield ("recv",)
        if res[0] == "fail":
            return ("err", res[1])
        return ("val", res[1][3])
    return prog


def surrogate_like(adj, owner, total):
    """Mini §IV surrogate mirroring the Rust protocol shape: rank owns
    nodes where owner[v]==r; for each oriented edge (v,u) with remote
    owner j, send N_v to j once; local pairs counted directly;
    opportunistic try_recv drains between nodes (like the Rust driver);
    completion notifiers; reduce at the end."""
    def prog(r):
        t = 0
        completions = 0

        def serve(payload):
            nonlocal t, completions
            if payload[0] == "done":
                completions += 1
            else:
                _, _v, nv = payload
                for u in nv:
                    if owner[u] == r:
                        t += len(set(adj[u]) & set(nv))

        for v in [v for v in range(len(adj)) if owner[v] == r]:
            nv = adj[v]
            sent_to = set()
            for u in nv:
                j = owner[u]
                if j == r:
                    t += len(set(adj[u]) & set(nv))
                elif j not in sent_to:
                    sent_to.add(j)
                    res = yield ("send", j, 0, 8 + 4 * len(nv), ("data", v, tuple(nv)))
                    if res[0] == "err":
                        return ("err", res[1])
            # opportunistic drain (Rust: `while let Some(..) = c.try_recv()`)
            while True:
                res = yield ("try_recv",)
                if res[0] != "msg":
                    break
                serve(res[1][3])
        for j in range(total):
            if j != r:
                res = yield ("send", j, 1, 8, ("done",))
                if res[0] == "err":
                    return ("err", res[1])
        while completions < total - 1:
            res = yield ("recv",)
            if res[0] == "fail":
                return ("err", res[1])
            serve(res[1][3])
        res = yield ("reduce", t)
        if res[0] == "fail":
            return ("err", res[1])
        return ("count", res[1])
    return prog


def reqreply(total):
    """Mini direct scheme: rank 0 requests a value from every other rank
    and waits for all replies; others serve one request then wait for a
    'done'."""
    def prog(r):
        if r == 0:
            pending = 0
            for j in range(1, total):
                res = yield ("send", j, 0, 16, ("req",))
                if res[0] == "err":
                    return ("err", res[1])
                pending += 1
            acc = 0
            while pending:
                res = yield ("recv",)
                if res[0] == "fail":
                    return ("err", res[1])
                acc += res[1][3][1]
                pending -= 1
            for j in range(1, total):
                res = yield ("send", j, 1, 8, ("fin",))
                if res[0] == "err":
                    return ("err", res[1])
            return ("val", acc)
        res = yield ("recv",)
        if res[0] == "fail":
            return ("err", res[1])
        res = yield ("send", 0, 0, 12, ("rep", r * 11))
        if res[0] == "err":
            return ("err", res[1])
        res = yield ("recv",)
        if res[0] == "fail":
            return ("err", res[1])
        return "ok"
    return prog


def fifo_probe(total):
    def prog(r):
        if r == 0:
            for i in range(12):
                res = yield ("send", 1, 0, 8, i)
                if res[0] == "err":
                    return ("err", res[1])
            return "ok"
        got = []
        for _ in range(12):
            res = yield ("recv",)
            if res[0] == "fail":
                return ("err", res[1])
            got.append(res[1][3])
        return ("order", tuple(got))
    return prog


def rand_graph(rng, n, m):
    edges = set()
    while len(edges) < m:
        u, v = rng.randrange(n), rng.randrange(n)
        if u != v:
            edges.add((min(u, v), max(u, v)))
    adj = [[] for _ in range(n)]
    deg = [0] * n
    for u, v in edges:
        deg[u] += 1
        deg[v] += 1
    # orientation: lower (degree, id) points to higher
    order = sorted(range(n), key=lambda v: (deg[v], v))
    pos = {v: i for i, v in enumerate(order)}
    for u, v in edges:
        a, b = (u, v) if pos[u] < pos[v] else (v, u)
        adj[a].append(b)
    tri = 0
    es = set(edges)
    for v in range(n):
        for i, a in enumerate(adj[v]):
            for b in adj[v][i + 1:]:
                if (min(a, b), max(a, b)) in es:
                    tri += 1
    return adj, tri


def main():
    fails = 0

    def check(name, cond, detail=""):
        nonlocal fails
        if not cond:
            fails += 1
            print(f"FAIL {name} {detail}")

    # 1. replay determinism + 2. seed sensitivity (ring)
    hashes = []
    for seed in range(8):
        runs = [Sim(4, {r: ring_program(4) for r in range(4)}, seed).run()
                for _ in range(2)]
        a, b = runs
        check("replay-hash", a.trace.hash == b.trace.hash, f"seed={seed}")
        check("replay-result", a.result == b.result, f"seed={seed}")
        check("ring-vals", sorted(x[1] for x in a.result) == [0, 1, 4, 9], a.result)
        hashes.append(a.trace.hash)
    check("seed-diversity", len(set(hashes)) > 1, hashes)

    # 3. per-edge FIFO under jitter
    for seed in range(30):
        s = Sim(2, {r: fifo_probe(2) for r in range(2)}, seed, switch=0.0).run()
        check("fifo", s.result[1] == ("order", tuple(range(12))), f"seed={seed} {s.result[1]}")

    # 4. surrogate-like exactness over many schedules (+ stragglers)
    grng = random.Random(7)
    for case in range(6):
        n, m = 24, 60
        adj, tri = rand_graph(grng, n, m)
        for p in (2, 3, 5):
            owner = [min(v * p // n, p - 1) for v in range(n)]
            for seed in range(16):
                slow = {p - 1: 16} if seed % 4 == 3 else {}
                s = Sim(p, {r: surrogate_like(adj, owner, p) for r in range(p)},
                        seed, slow=slow).run()
                counts = {x[1] for x in s.result if x[0] == "count"}
                check("surrogate-exact", counts == {tri},
                      f"case={case} p={p} seed={seed} got={counts} want={tri}")
                check("drained", s.trace.delivered == s.trace.sends,
                      f"case={case} p={p} seed={seed}")

    # 5a. kill never hangs: every rank ends Done/Dead with a result
    for seed in range(12):
        s = Sim(3, {r: reqreply(3) for r in range(3)}, seed, kills={1: 1}).run()
        check("kill-terminates", all(r is not None for r in s.result), s.result)
        errs = [r for r in s.result if isinstance(r, tuple) and r[0] == "err"]
        check("kill-errs", len(errs) >= 1, s.result)
        s2 = Sim(3, {r: reqreply(3) for r in range(3)}, seed, kills={1: 1}).run()
        check("kill-replay", s.result == s2.result and s.trace.hash == s2.trace.hash,
              f"seed={seed}")

    # 5b. drop trips the guard deterministically
    for seed in range(12):
        s = Sim(3, {r: reqreply(3) for r in range(3)}, seed, drops={(0, 1, 1)}).run()
        guard_errs = [r for r in s.result
                      if isinstance(r, tuple) and r[0] == "err" and "guard" in r[1]]
        check("drop-guard", len(guard_errs) >= 1, s.result)
        s2 = Sim(3, {r: reqreply(3) for r in range(3)}, seed, drops={(0, 1, 1)}).run()
        check("drop-replay", s.result == s2.result and s.trace.hash == s2.trace.hash,
              f"seed={seed}")

    # 6. barrier + reduce complete; death in reduce guards out
    def red_prog(total):
        def prog(r):
            res = yield ("barrier",)
            if res[0] == "fail":
                return ("err", res[1])
            res = yield ("reduce", r + 1)
            if res[0] == "fail":
                return ("err", res[1])
            return ("val", res[1])
        return prog

    for seed in range(10):
        s = Sim(5, {r: red_prog(5) for r in range(5)}, seed).run()
        check("reduce-total", all(x == ("val", 15) for x in s.result), s.result)
        s = Sim(4, {r: red_prog(4) for r in range(4)}, seed, kills={2: 1}).run()
        check("reduce-death", all(r is not None for r in s.result), s.result)
        check("reduce-death-err",
              any(isinstance(r, tuple) and r[0] == "err" for r in s.result), s.result)

    print("PASS" if fails == 0 else f"{fails} FAILURES")
    return 0 if fails == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
