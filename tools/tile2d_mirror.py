#!/usr/bin/env python3
"""Differential mirror of the 2D tile driver (partition/tile2d +
algo/tile2d + comm/coalesce) — authoring-container validation: the image
has no Rust toolchain, so the tiling math, the three-phase exchange and
the coalescing-frame accounting are proven out here before tier-1 runs
post-merge.

Mirrors DESIGN.md §14: `grid_for` (nearest r·c ≤ P minimizing 1/r + 1/c,
remainder ranks idle), the fixed-seed degree-decorrelating shuffle
(`tile2d::shuffled` — contiguous blocks over raw degree order pile
hub–hub edges into the corner tile and the traffic bound dies),
out/in-degree-balanced row/column blocks, tiles as
restricted row slices, the masked-SpGEMM formulation
T = Σ over mask edges (v, u) of |N⁺(v) ∩ N⁻(u)|, watermark-bounded
coalescing frames ([tag, len, payload…] records, frame bytes =
8 + 4·words), and the per-rank traffic accounting of bench-comm
(surrogate LastProc sends of 8 + 4·d̂ᵥ, direct 16 B requests +
12 + 4·d̂ᵤ replies, tile2d (c−1)·row-frames + (r−1)·col-frames).

Validated properties (each a design-level acceptance criterion):
  1. grid factorization pins (1→1×1 … 16→4×4; P=5 → 2×2 + 1 idle,
     13 → 3×4 + 1 idle) and coords/rank_of round-trips;
  2. tile cover exactness: every oriented edge lands in exactly one
     tile and the union over tiles is E, for P ∈ {1,2,4,5,6,8,9,13,16};
     and the shuffle keeps the max tile within 1.35× the mean where raw
     degree order reaches ≈ 1.9× by P = 16 (count relabel-invariant);
  3. coalescing: record conservation through frames, watermark bound
     (every non-final frame ≥ watermark words, closed exactly at the
     first crossing), deterministic packing, aggregation ratio > 1;
  4. three-phase exactness: rows/columns assembled ONLY from broadcast
     pieces reconstruct N⁺/N⁻ exactly, and the tiled count equals the
     node-iterator oracle across PA / R-MAT / ER × P ∈ {2,4,8,9,16}
     (remainder-rank cells contribute 0);
  5. tile partials are globally disjoint: per-tile sums add to the
     oracle with no edge counted twice (the ft/ salvage contract);
  6. the tentpole: tile2d max per-rank sent bytes strictly fall
     P = 4 → 9 → 16 (≈ 1/√P) and land below the best 1D driver at
     P = 16 on the skewed PA workload.

With --bench OUT.json, additionally derives BENCH_comm.json on the
acceptance workloads (pa:100000:64, rmat:16:16, er:200000:16 at
P ∈ {4, 9, 16}): max/total per-rank sent bytes, frames vs logical
records, aggregation ratio and the (identical-by-construction) frame-plan
prediction, with the same gates bench-comm enforces. The mirror's
generators are design-level (Python RNG), so absolute byte counts differ
from the Rust run; regenerate natively with
`cargo run --release -- bench-comm`.

Run: python3 tools/tile2d_mirror.py [--bench OUT.json]
"""

import bisect
import json
import random
import sys

WATERMARK_WORDS = 1024


# ---------------------------------------------------------------------------
# Workloads (design-level; mirrors gen/ shapes, not the Rust RNG streams)
# ---------------------------------------------------------------------------


def pa_graph(n, d, seed):
    """Preferential attachment, d/2 edges per arriving node (pa:N:D)."""
    rng = random.Random(seed)
    half = d // 2
    endpoints = []
    adj = [set() for _ in range(n)]
    for v in range(n):
        if v == 0:
            continue
        for _ in range(min(half, v)):
            for _ in range(8):  # rejection: simple graph
                u = endpoints[rng.randrange(len(endpoints))] if endpoints \
                    else rng.randrange(v)
                if u != v and u not in adj[v]:
                    break
            else:
                continue
            adj[v].add(u)
            adj[u].add(v)
            endpoints.append(u)
            endpoints.append(v)
    return adj


def rmat_graph(scale, d, seed):
    """R-MAT with the standard (0.57, 0.19, 0.19, 0.05) quadrant mix
    (rmat:SCALE:D → 2^SCALE nodes, ~2^SCALE·D/2 distinct edges)."""
    rng = random.Random(seed)
    n = 1 << scale
    target = n * d // 2
    adj = [set() for _ in range(n)]
    edges = 0
    attempts = 0
    while edges < target and attempts < target * 8:
        attempts += 1
        u = v = 0
        for _ in range(scale):
            r = rng.random()
            bu = 1 if r >= 0.57 + 0.19 else 0
            bv = 1 if (r >= 0.57 and r < 0.57 + 0.19) or r >= 0.57 + 0.19 + 0.19 else 0
            u = (u << 1) | bu
            v = (v << 1) | bv
        if u == v or v in adj[u]:
            continue
        adj[u].add(v)
        adj[v].add(u)
        edges += 1
    return adj


def er_graph(n, d, seed):
    """Erdős–Rényi G(n, m) with m = n·d/2 distinct edges (er:N:D)."""
    rng = random.Random(seed)
    target = n * d // 2
    adj = [set() for _ in range(n)]
    edges = 0
    while edges < target:
        u = rng.randrange(n)
        v = rng.randrange(n)
        if u == v or v in adj[u]:
            continue
        adj[u].add(v)
        adj[v].add(u)
        edges += 1
    return adj


def build_workload(spec, seed=1):
    kind, a, b = spec.split(":")
    if kind == "pa":
        return pa_graph(int(a), int(b), seed)
    if kind == "rmat":
        return rmat_graph(int(a), int(b), seed)
    if kind == "er":
        return er_graph(int(a), int(b), seed)
    raise ValueError(f"unknown workload spec {spec}")


def orient(adj):
    """Degree-order, relabel, keep out-neighbors as sorted lists
    (v → u iff v ≺ u) — graph::ordering::Oriented."""
    n = len(adj)
    order = sorted(range(n), key=lambda v: (len(adj[v]), v))
    new_id = [0] * n
    for i, v in enumerate(order):
        new_id[v] = i
    out = [[] for _ in range(n)]
    for v in range(n):
        nv = new_id[v]
        for u in adj[v]:
            nu = new_id[u]
            if nv < nu:
                out[nv].append(nu)
    for lst in out:
        lst.sort()
    return out


def shuffle_graph(out, seed=0x7119_2D5E_ED00_91F3):
    """tile2d::shuffled — fixed-seed degree-decorrelating relabel applied
    before tiling. Degree order piles hub–hub edges into the corner tile
    (contiguous interval blocks cannot balance an upper-triangular
    matrix); over shuffled ids every block is a uniform vertex sample.
    Triangle count is relabel-invariant."""
    n = len(out)
    rng = random.Random(seed)
    perm = list(range(n))
    for i in range(n - 1, 0, -1):
        j = rng.randrange(i + 1)
        perm[i], perm[j] = perm[j], perm[i]
    out2 = [[] for _ in range(n)]
    for v in range(n):
        out2[perm[v]] = sorted(perm[u] for u in out[v])
    return out2


def oracle_count(out):
    """seq::node_iterator — Σ |N⁺(v) ∩ N⁺(u)| over oriented edges."""
    t = 0
    sets = [set(lst) for lst in out]
    for v in range(len(out)):
        sv = sets[v]
        for u in out[v]:
            t += len(sv & sets[u])
    return t


# ---------------------------------------------------------------------------
# partition/tile2d mirror
# ---------------------------------------------------------------------------


def grid_for(p):
    """Exact mirror of partition/tile2d.rs::grid_for."""
    assert p >= 1
    best = (1, p)
    best_cost = float("inf")
    r = 1
    while r * r <= p:
        c = p // r
        cost = 1.0 / r + 1.0 / c
        better = cost < best_cost - 1e-12 or (
            abs(cost - best_cost) <= 1e-12
            and (r * c > best[0] * best[1]
                 or (r * c == best[0] * best[1] and c - r < best[1] - best[0]))
        )
        if better:
            best = (r, c)
            best_cost = cost
        r += 1
    return best


def balanced_ranges(cost, k):
    """Consecutive ranges with near-equal cost prefix (design-level
    mirror of partition/balance.rs)."""
    prefix = [0]
    for c in cost:
        prefix.append(prefix[-1] + c)
    total = prefix[-1]
    cuts = [0]
    for i in range(1, k):
        cut = bisect.bisect_left(prefix, total * i / k)
        cuts.append(min(max(cuts[-1], cut), len(cost)))
    cuts.append(len(cost))
    return list(zip(cuts[:-1], cuts[1:]))


def layout(out, p):
    """Row blocks balance out-degree, column blocks balance in-degree."""
    r, c = grid_for(p)
    n = len(out)
    row_cost = [len(out[v]) + 1 for v in range(n)]
    col_cost = [1] * n
    for v in range(n):
        for u in out[v]:
            col_cost[u] += 1
    return {
        "grid": (r, c),
        "procs": p,
        "rows": balanced_ranges(row_cost, r),
        "cols": balanced_ranges(col_cost, c),
    }


def extract_tiles(out, lay):
    """Per active rank: {v: sorted piece of N⁺(v) inside the column
    block} (the OwnedPartition::from_rows slices). Remainder ranks get
    an empty dict."""
    r, c = lay["grid"]
    tiles = [dict() for _ in range(lay["procs"])]
    for i, (rlo, rhi) in enumerate(lay["rows"]):
        for j, (clo, chi) in enumerate(lay["cols"]):
            rank = i * c + j
            tile = tiles[rank]
            for v in range(rlo, rhi):
                nv = out[v]
                lo = bisect.bisect_left(nv, clo)
                hi = bisect.bisect_left(nv, chi)
                if hi > lo:
                    tile[v] = nv[lo:hi]
    return tiles


# ---------------------------------------------------------------------------
# comm/coalesce mirror
# ---------------------------------------------------------------------------


class Coalescer:
    """CoalescingBuffer: [tag, len, payload…] records, frame closed at
    the first crossing of the watermark. Frame bytes = 8 + 4·words."""

    def __init__(self, watermark=WATERMARK_WORDS):
        assert watermark >= 1
        self.watermark = watermark
        self.words = []
        self.items = 0
        self.frames = []  # (records, words) per closed frame

    def push(self, tag, payload):
        self.words.extend((tag, len(payload)))
        self.words.extend(payload)
        self.items += 1
        if len(self.words) >= self.watermark:
            self._close()

    def _close(self):
        self.frames.append((self.items, len(self.words)))
        self.words = []
        self.items = 0

    def flush(self):
        if self.words:
            self._close()
        return self.frames


def frame_bytes(words):
    return 8 + 4 * words


def bcast_plan(tile, col_block):
    """algo/tile2d::bcast_plan: row frames (one record per non-empty row
    piece, row-ascending) + column frames (tile CSC, column-ascending)."""
    rows = Coalescer()
    for v in sorted(tile):
        rows.push(v, tile[v])
    row_frames = rows.flush()

    clo, chi = col_block
    csc = [[] for _ in range(chi - clo)]
    for v in sorted(tile):
        for u in tile[v]:
            csc[u - clo].append(v)
    cols = Coalescer()
    for k, lst in enumerate(csc):
        if lst:
            cols.push(clo + k, lst)
    return row_frames, cols.flush(), csc


def plan_cost(frames):
    return (
        len(frames),
        sum(rec for rec, _ in frames),
        sum(frame_bytes(w) for _, w in frames),
    )


# ---------------------------------------------------------------------------
# Three-phase exchange + per-driver traffic accounting
# ---------------------------------------------------------------------------


def tile2d_count(out, lay, tiles):
    """Count through the three-phase exchange, assembling rows/columns
    ONLY from the broadcast pieces (never from `out` directly), exactly
    as a rank of the r×c grid would. Returns (total, per-tile list)."""
    r, c = lay["grid"]
    per_tile = []
    total = 0
    for i in range(r):
        # Phase 1 (row broadcast): grid row i assembles N⁺(v) for
        # v ∈ R_i from the c tile pieces, column-ascending.
        rows = {}
        for j in range(c):
            for v, piece in tiles[i * c + j].items():
                rows.setdefault(v, []).extend(piece)
        row_sets = {v: set(lst) for v, lst in rows.items()}
        for j in range(c):
            rank = i * c + j
            clo, chi = lay["cols"][j]
            # Phase 2 (column broadcast): grid column j assembles the
            # in-columns N⁻(u) for u ∈ C_j from the r tile CSCs.
            col_sets = [set() for _ in range(chi - clo)]
            for ii in range(r):
                _, _, csc = bcast_plan(tiles[ii * c + j], (clo, chi))
                for k, lst in enumerate(csc):
                    col_sets[k].update(lst)
            # Phase 3: one intersection per local mask edge.
            t = 0
            for v, piece in tiles[rank].items():
                rv = row_sets[v]
                for u in piece:
                    t += len(rv & col_sets[u - clo])
            per_tile.append(((i, j), t))
            total += t
    return total, per_tile


def tile2d_traffic(out, lay, tiles):
    """Per-rank (bytes, frames, records) of both broadcasts — each frame
    clones to every grid-row / grid-column peer."""
    r, c = lay["grid"]
    stats = []
    for rank in range(lay["procs"]):
        if rank >= r * c:
            stats.append((0, 0, 0))
            continue
        i, j = divmod(rank, c)
        row_frames, col_frames, _ = bcast_plan(tiles[rank], lay["cols"][j])
        rf, rr, rb = plan_cost(row_frames)
        cf, cr, cb = plan_cost(col_frames)
        stats.append((
            (c - 1) * rb + (r - 1) * cb,
            (c - 1) * rf + (r - 1) * cf,
            (c - 1) * rr + (r - 1) * cr,
        ))
    return stats


def owner_of(ranges, n):
    owner = [0] * n
    for i, (lo, hi) in enumerate(ranges):
        for v in range(lo, hi):
            owner[v] = i
    return owner


def surrogate_traffic(out, ranges, owner):
    """§IV surrogate LastProc walk: one 8 + 4·d̂ᵥ message per (v, owner)
    transition (sim/space_efficient.rs accounting, == real run)."""
    bytes_per = [0] * len(ranges)
    msgs_per = [0] * len(ranges)
    for i, (lo, hi) in enumerate(ranges):
        for v in range(lo, hi):
            nv = out[v]
            last = -1
            for u in nv:
                j = owner[u]
                if j != i and j != last:
                    bytes_per[i] += 8 + 4 * len(nv)
                    msgs_per[i] += 1
                    last = j
    return bytes_per, msgs_per


def direct_traffic(out, ranges, owner):
    """§IV-C request/reply: 16 B request i→j + (12 + 4·d̂ᵤ) B reply j→i
    per remote mask edge (redundant re-fetches included — the scheme's
    documented flaw). Logical records; framing repacks them."""
    bytes_per = [0] * len(ranges)
    msgs_per = [0] * len(ranges)
    for i, (lo, hi) in enumerate(ranges):
        for v in range(lo, hi):
            for u in out[v]:
                j = owner[u]
                if j != i:
                    bytes_per[i] += 16
                    msgs_per[i] += 1
                    bytes_per[j] += 12 + 4 * len(out[u])
                    msgs_per[j] += 1
    return bytes_per, msgs_per


# ---------------------------------------------------------------------------
# Property checks
# ---------------------------------------------------------------------------

GRID_PINS = [
    (1, 1, 1), (2, 1, 2), (3, 1, 3), (4, 2, 2), (5, 2, 2), (6, 2, 3),
    (8, 2, 4), (9, 3, 3), (12, 3, 4), (13, 3, 4), (16, 4, 4),
]


def main():
    failures = []

    def check(name, cond, detail=""):
        status = "ok" if cond else "FAIL"
        print(f"  [{status}] {name}" + (f" — {detail}" if detail and not cond else ""))
        if not cond:
            failures.append(name)

    print("== 1. grid factorization ==")
    for p, r, c in GRID_PINS:
        g = grid_for(p)
        check(f"grid_for({p}) == {r}x{c}", g == (r, c), f"got {g}")
        check(f"grid_for({p}) fits", g[0] * g[1] <= p)
    r, c = grid_for(13)
    for rank in range(r * c):
        i, j = divmod(rank, c)
        check(f"coords({rank}) round-trips", i * c + j == rank)

    print("== 2. tile cover exactness ==")
    adj = pa_graph(600, 8, 11)
    out = orient(adj)
    full = sorted((v, u) for v in range(len(out)) for u in out[v])
    for p in [1, 2, 4, 5, 6, 8, 9, 13, 16]:
        lay = layout(out, p)
        tiles = extract_tiles(out, lay)
        union = sorted(
            (v, u) for tile in tiles for v, piece in tile.items() for u in piece
        )
        check(f"P={p}: tiles tile E exactly", union == full)
        r, c = lay["grid"]
        for rank in range(r * c, p):
            check(f"P={p}: remainder rank {rank} empty", not tiles[rank])

    print("== 2b. shuffle balances tiles on skewed graphs ==")
    sh = shuffle_graph(out)
    check("shuffle preserves the count",
          oracle_count(sh) == oracle_count(out))
    for p in [4, 9, 16]:
        lay = layout(sh, p)
        tiles = extract_tiles(sh, lay)
        r, c = lay["grid"]
        sizes = [sum(len(x) for x in t.values()) for t in tiles[: r * c]]
        avg = len(full) / (r * c)
        check(f"P={p}: max tile near mean", max(sizes) <= avg * 1.35,
              f"max {max(sizes)} vs avg {avg:.0f}")

    print("== 3. coalescing frames ==")
    buf = Coalescer(watermark=16)
    payloads = [(t, list(range(t % 7))) for t in range(100)]
    for tag, pl in payloads:
        buf.push(tag, pl)
    frames = buf.flush()
    total_records = sum(rec for rec, _ in frames)
    total_words = sum(w for _, w in frames)
    want_words = sum(2 + len(pl) for _, pl in payloads)
    check("records conserved", total_records == len(payloads),
          f"{total_records} != {len(payloads)}")
    check("words conserved", total_words == want_words)
    check("non-final frames at watermark",
          all(w >= 16 for _, w in frames[:-1]))
    check("bounded overshoot (one record)",
          all(w < 16 + 2 + 6 for _, w in frames))
    buf2 = Coalescer(watermark=16)
    for tag, pl in payloads:
        buf2.push(tag, pl)
    check("packing deterministic", buf2.flush() == frames)

    print("== 4. three-phase exactness (count == oracle) ==")
    workloads = [("pa:700:8", 5), ("rmat:9:6", 7), ("er:500:6", 3)]
    for spec, seed in workloads:
        out = orient(build_workload(spec, seed))
        oracle = oracle_count(out)
        # The driver tiles the shuffled graph; the count must still equal
        # the oracle of the original labeling (relabel invariance).
        sh = shuffle_graph(out)
        for p in [2, 4, 8, 9, 16]:
            lay = layout(sh, p)
            tiles = extract_tiles(sh, lay)
            total, per_tile = tile2d_count(sh, lay, tiles)
            check(f"{spec} P={p}: tiled count == oracle", total == oracle,
                  f"{total} != {oracle}")
            # 5. disjoint partials: Σ per-tile == total (no edge twice is
            # implied by the cover check; the sums must also add up).
            check(f"{spec} P={p}: tile partials sum",
                  sum(t for _, t in per_tile) == total)

    print("== 6. per-rank traffic falls with P (PA) ==")
    out = orient(pa_graph(20000, 30, 7))
    sh = shuffle_graph(out)
    row_cost = [len(out[v]) + 1 for v in range(len(out))]
    prev = None
    tile_curve = []
    for p in [4, 9, 16]:
        lay = layout(sh, p)
        tiles = extract_tiles(sh, lay)
        stats = tile2d_traffic(sh, lay, tiles)
        mx = max(b for b, _, _ in stats)
        tile_curve.append(mx)
        if prev is not None:
            check(f"tile2d max-rank bytes fall at P={p}", mx < prev,
                  f"{prev} -> {mx}")
        prev = mx
        frames = sum(f for _, f, _ in stats)
        records = sum(rec for _, _, rec in stats)
        check(f"P={p}: aggregation ratio > 1", records > frames,
              f"records {records} <= frames {frames}")
    ranges = balanced_ranges(row_cost, 16)
    owner = owner_of(ranges, len(out))
    sb, _ = surrogate_traffic(out, ranges, owner)
    db, _ = direct_traffic(out, ranges, owner)
    best_1d = min(max(sb), max(db))
    check("tile2d < best 1D at P=16", tile_curve[-1] < best_1d,
          f"{tile_curve[-1]} !< {best_1d}")

    print()
    if failures:
        print(f"FAILED: {len(failures)} checks: {failures}")
        return 1
    print("all checks passed")
    return 0


# ---------------------------------------------------------------------------
# --bench: derive BENCH_comm.json
# ---------------------------------------------------------------------------

BENCH_WORKLOADS = ["pa:100000:64", "rmat:16:16", "er:200000:16"]
BENCH_PROCS = [4, 9, 16]


def bench(out_path):
    report = {
        "columns": [
            "workload", "algorithm", "P", "max_rank_sent_bytes",
            "total_sent_bytes", "frames", "logical_msgs", "agg_ratio",
            "pred_total_bytes",
        ],
        "rows": [],
        "notes": [],
    }
    for spec in BENCH_WORKLOADS:
        out = orient(build_workload(spec, 1))
        n = len(out)
        m = sum(len(lst) for lst in out)
        print(f"bench-comm(mirror): workload={spec} n={n} m={m}")
        sh = shuffle_graph(out)
        row_cost = [len(out[v]) + 1 for v in range(n)]
        tile_prev = None
        for p in BENCH_PROCS:
            ranges = balanced_ranges(row_cost, p)
            owner = owner_of(ranges, n)
            lay = layout(sh, p)
            tiles = extract_tiles(sh, lay)
            tstats = tile2d_traffic(sh, lay, tiles)
            sb, sm = surrogate_traffic(out, ranges, owner)
            db, dm = direct_traffic(out, ranges, owner)
            rows = {
                # PATRIC is reduce-only: one 8 B contribution per
                # non-root rank, no data plane.
                "surrogate": (sb, sum(sm), 0, sum(sm)),
                "direct": (db, sum(dm), 0, sum(dm)),
                "patric": ([8] * (p - 1) + [0], p - 1, 0, p - 1),
                "tile2d": (
                    [b for b, _, _ in tstats],
                    sum(rec for _, _, rec in tstats),
                    sum(f for _, f, _ in tstats),
                    sum(rec for _, _, rec in tstats),
                ),
            }
            best_1d = None
            tile_max = 0
            for name in ["surrogate", "direct", "patric", "tile2d"]:
                bytes_per, _, frames, logical = rows[name]
                total_b = sum(bytes_per)
                max_rank = max(bytes_per)
                agg = (logical / frames) if frames else 1.0
                pred = total_b if name == "tile2d" else 0
                print(f"  {name:>9} P={p:<2}: max-rank {max_rank} B, "
                      f"total {total_b} B, frames {frames}, records {logical}, "
                      f"agg {agg:.1f}x")
                report["rows"].append({
                    "workload": spec,
                    "algorithm": name,
                    "P": p,
                    "max_rank_sent_bytes": max_rank,
                    "total_sent_bytes": total_b,
                    "frames": frames,
                    "logical_msgs": logical,
                    "agg_ratio": round(agg, 6),
                    "pred_total_bytes": pred,
                })
                if name in ("surrogate", "direct"):
                    best_1d = max_rank if best_1d is None else min(best_1d, max_rank)
                if name == "tile2d":
                    tile_max = max_rank
            if spec.startswith("pa:"):
                if tile_prev is not None and tile_max >= tile_prev:
                    print(f"GATE FAIL: tile2d per-rank bytes did not fall: "
                          f"{tile_prev} -> {tile_max} at P={p}")
                    return 1
                tile_prev = tile_max
                if p == BENCH_PROCS[-1] and tile_max >= best_1d:
                    print(f"GATE FAIL: tile2d {tile_max} !< best 1D {best_1d}")
                    return 1
    report["notes"] = [
        "max_rank_sent_bytes is the per-rank data-plane traffic (control markers "
        "excluded); agg_ratio = logical records / frames for coalescing drivers, "
        "1.0 otherwise; pred_total_bytes (tile2d) replays the exact frame plan "
        "in the cost model",
        "derived by tools/tile2d_mirror.py (design-level Python generators; the "
        "toolchain-free authoring container has no cargo) — regenerate natively "
        "with `cargo run --release -- bench-comm`",
    ]
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)
        f.write("\n")
    print(f"[written: {out_path}]")
    return 0


if __name__ == "__main__":
    rc = main()
    if rc == 0 and "--bench" in sys.argv:
        rc = bench(sys.argv[sys.argv.index("--bench") + 1])
    sys.exit(rc)
