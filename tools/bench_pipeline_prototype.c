/* bench_pipeline_prototype.c — measured stand-in for `tricount bench-pipeline`.
 *
 * The authoring container for PR 3 ships no Rust toolchain, so the first
 * committed BENCH_pipeline.json is produced by this C mirror of the exact
 * algorithms in rust/src/graph/builder.rs (O(m) two-pass counting/radix CSR
 * build with disjoint per-(thread,bucket) scatter regions vs. the seed's
 * comparison-sort build), rust/src/graph/io.rs (byte-level parse),
 * rust/src/graph/relabel.rs (counting-sort permutation) and
 * rust/src/graph/ordering.rs (parallel orientation + hub bitmap packing).
 * Regenerate natively with:  cargo run --release -- bench-pipeline
 * (CI runs a small-preset smoke of the native path on every push.)
 *
 * Build/run:  gcc -O2 -pthread -o /tmp/bpp tools/bench_pipeline_prototype.c
 *             /tmp/bpp > BENCH_pipeline.json
 *
 * The prototype verifies, like the native subcommand, that the radix build
 * at every thread count is byte-identical to the comparison-sort build and
 * exits nonzero on divergence.
 */
#include <pthread.h>
#include <stdint.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>
#include <time.h>
#include <unistd.h>

static double now_s(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return (double)ts.tv_sec + 1e-9 * (double)ts.tv_nsec;
}

/* ---------- tiny parallel-for (same near-equal chunking as par::ranges) -- */
typedef void (*part_fn)(int part, size_t lo, size_t hi);
typedef struct {
    part_fn fn;
    int part;
    size_t lo, hi;
} job_t;
static void *job_main(void *p) {
    job_t *j = (job_t *)p;
    j->fn(j->part, j->lo, j->hi);
    return NULL;
}
static void par_for(int t, size_t len, part_fn fn) {
    if (t <= 1) {
        fn(0, 0, len);
        return;
    }
    pthread_t th[64];
    job_t jobs[64];
    size_t base = len / (size_t)t, rem = len % (size_t)t, at = 0;
    for (int i = 0; i < t; i++) {
        size_t sz = base + ((size_t)i < rem ? 1 : 0);
        jobs[i] = (job_t){fn, i, at, at + sz};
        at += sz;
        pthread_create(&th[i], NULL, job_main, &jobs[i]);
    }
    for (int i = 0; i < t; i++) pthread_join(th[i], NULL);
}

/* ---------- rng ---------------------------------------------------------- */
static uint64_t rng_state;
static uint64_t rng_next(void) {
    uint64_t x = rng_state;
    x ^= x << 13;
    x ^= x >> 7;
    x ^= x << 17;
    return rng_state = x;
}
static uint32_t rng_below(uint32_t n) { return (uint32_t)(rng_next() % n); }

/* ---------- shared build state ------------------------------------------ */
static uint32_t g_n;
static size_t g_m; /* input edge count */
static uint32_t *g_eu, *g_ev;
static int g_T;
static uint32_t **g_hist; /* [T][n] */
static uint64_t *g_off;   /* n+1 */
static uint64_t *g_cur;   /* T*n */
static uint32_t *g_bydst; /* 2m' */
static uint32_t *g_rows;  /* 2m' */
static uint64_t *g_uniq;  /* n+1 */
static uint32_t *g_tgt;   /* final targets */
static size_t g_tgt_len;

static void norm_phase(int p, size_t lo, size_t hi) {
    uint32_t *h = g_hist[p];
    for (size_t i = lo; i < hi; i++) {
        uint32_t u = g_eu[i], v = g_ev[i];
        if (u > v) {
            g_eu[i] = v;
            g_ev[i] = u;
            u = g_eu[i];
            v = g_ev[i];
        }
        h[u]++;
        h[v]++;
    }
}
static void pass1_phase(int p, size_t lo, size_t hi) {
    uint64_t *cur = g_cur + (size_t)p * g_n;
    for (size_t i = lo; i < hi; i++) {
        uint32_t u = g_eu[i], v = g_ev[i];
        g_bydst[cur[v]++] = u;
        g_bydst[cur[u]++] = v;
    }
}
static void pass2a_phase(int p, size_t lo, size_t hi) {
    uint32_t *h = g_hist[p];
    memset(h, 0, (size_t)g_n * 4);
    for (size_t i = g_off[lo]; i < g_off[hi]; i++) h[g_bydst[i]]++;
}
static void pass2b_phase(int p, size_t lo, size_t hi) {
    uint64_t *cur = g_cur + (size_t)p * g_n;
    for (size_t v = lo; v < hi; v++)
        for (size_t i = g_off[v]; i < g_off[v + 1]; i++)
            g_rows[cur[g_bydst[i]]++] = (uint32_t)v;
}
static void dedup_phase(int p, size_t lo, size_t hi) {
    (void)p;
    for (size_t v = lo; v < hi; v++) {
        size_t s = g_off[v], e = g_off[v + 1], w = s;
        for (size_t i = s; i < e; i++) {
            uint32_t x = g_rows[i];
            if (w == s || g_rows[w - 1] != x) g_rows[w++] = x;
        }
        g_uniq[v + 1] = w - s;
    }
}
static void compact_phase(int p, size_t lo, size_t hi) {
    (void)p;
    for (size_t v = lo; v < hi; v++) {
        size_t cnt = g_uniq[v + 1] - g_uniq[v];
        memcpy(g_tgt + g_uniq[v], g_rows + g_off[v], cnt * 4);
    }
}

/* Radix build (mirror of from_edge_list_threads). Caller frees off/tgt. */
static void radix_build(uint32_t n, uint32_t *eu, uint32_t *ev, size_t m, int T,
                        uint64_t **off_out, uint32_t **tgt_out, size_t *tl_out) {
    g_n = n;
    g_m = m;
    g_eu = eu;
    g_ev = ev;
    g_T = T;
    g_hist = malloc((size_t)T * sizeof(uint32_t *));
    for (int i = 0; i < T; i++) g_hist[i] = calloc(n, 4);
    par_for(T, m, norm_phase);
    g_off = calloc(n + 1, 8);
    for (uint32_t v = 0; v < n; v++) {
        uint64_t s = 0;
        for (int i = 0; i < T; i++) s += g_hist[i][v];
        g_off[v + 1] = g_off[v] + s;
    }
    g_cur = malloc((size_t)T * n * 8);
    for (uint32_t v = 0; v < n; v++) {
        uint64_t at = g_off[v];
        for (int i = 0; i < T; i++) {
            g_cur[(size_t)i * n + v] = at;
            at += g_hist[i][v];
        }
    }
    size_t arcs = g_off[n];
    g_bydst = malloc(arcs * 4);
    par_for(T, m, pass1_phase);
    par_for(T, n, pass2a_phase);
    for (uint32_t v = 0; v < n; v++) {
        uint64_t at = g_off[v];
        for (int i = 0; i < T; i++) {
            g_cur[(size_t)i * n + v] = at;
            at += g_hist[i][v];
        }
    }
    g_rows = malloc(arcs * 4);
    par_for(T, n, pass2b_phase);
    free(g_bydst);
    g_uniq = calloc(n + 1, 8);
    par_for(T, n, dedup_phase);
    for (uint32_t v = 0; v < n; v++) g_uniq[v + 1] += g_uniq[v];
    g_tgt_len = g_uniq[n];
    g_tgt = malloc(g_tgt_len * 4);
    par_for(T, n, compact_phase);
    free(g_rows);
    free(g_cur);
    for (int i = 0; i < T; i++) free(g_hist[i]);
    free(g_hist);
    free(g_off);
    *off_out = g_uniq;
    *tgt_out = g_tgt;
    *tl_out = g_tgt_len;
}

/* Comparison-sort build (mirror of from_edge_list_sort_baseline). */
static int cmp_u64(const void *a, const void *b) {
    uint64_t x = *(const uint64_t *)a, y = *(const uint64_t *)b;
    return x < y ? -1 : x > y ? 1 : 0;
}
static int cmp_u32(const void *a, const void *b) {
    uint32_t x = *(const uint32_t *)a, y = *(const uint32_t *)b;
    return x < y ? -1 : x > y ? 1 : 0;
}
static void sort_build(uint32_t n, const uint32_t *eu, const uint32_t *ev, size_t m,
                       uint64_t **off_out, uint32_t **tgt_out, size_t *tl_out) {
    uint64_t *keys = malloc(m * 8);
    for (size_t i = 0; i < m; i++) {
        uint32_t u = eu[i], v = ev[i];
        if (u > v) {
            uint32_t t = u;
            u = v;
            v = t;
        }
        keys[i] = ((uint64_t)u << 32) | v;
    }
    qsort(keys, m, 8, cmp_u64);
    size_t w = 0;
    for (size_t i = 0; i < m; i++)
        if (w == 0 || keys[w - 1] != keys[i]) keys[w++] = keys[i];
    uint64_t *off = calloc(n + 1, 8);
    for (size_t i = 0; i < w; i++) {
        off[(keys[i] >> 32) + 1]++;
        off[(keys[i] & 0xffffffffu) + 1]++;
    }
    for (uint32_t v = 0; v < n; v++) off[v + 1] += off[v];
    uint64_t *cur = malloc((n + 1) * 8);
    memcpy(cur, off, (n + 1) * 8);
    size_t tl = off[n];
    uint32_t *tgt = malloc(tl * 4);
    for (size_t i = 0; i < w; i++) {
        uint32_t u = (uint32_t)(keys[i] >> 32), v = (uint32_t)(keys[i] & 0xffffffffu);
        tgt[cur[u]++] = v;
        tgt[cur[v]++] = u;
    }
    for (uint32_t v = 0; v < n; v++)
        qsort(tgt + off[v], off[v + 1] - off[v], 4, cmp_u32);
    free(cur);
    free(keys);
    *off_out = off;
    *tgt_out = tgt;
    *tl_out = tl;
}

/* ---------- parse stage (mirror of io.rs byte scanner) ------------------- */
static char *g_text;
static size_t g_text_len;
static void make_text(const uint32_t *eu, const uint32_t *ev, size_t m) {
    g_text = malloc(m * 16 + 64);
    size_t at = (size_t)sprintf(g_text, "# bench prototype m=%zu\n", m);
    for (size_t i = 0; i < m; i++)
        at += (size_t)sprintf(g_text + at, "%u %u\n", eu[i], ev[i]);
    g_text_len = at;
}
/* Scan bytes -> normalized (min,max) pairs; then sort+dedup and build (the
 * io.rs pipeline: compaction is an identity map here, ids are 0..n). */
static double parse_stage(uint32_t n, size_t m_hint, int T) {
    double t0 = now_s();
    uint64_t *keys = malloc((m_hint + 1) * 8);
    size_t cnt = 0, i = 0;
    const char *b = g_text;
    while (i < g_text_len) {
        while (i < g_text_len && (b[i] == ' ' || b[i] == '\t' || b[i] == '\r')) i++;
        if (i >= g_text_len) break;
        if (b[i] == '\n') {
            i++;
            continue;
        }
        if (b[i] == '#' || b[i] == '%') {
            while (i < g_text_len && b[i] != '\n') i++;
            continue;
        }
        uint64_t u = 0, v = 0;
        while (i < g_text_len && b[i] >= '0' && b[i] <= '9') u = u * 10 + (uint64_t)(b[i++] - '0');
        while (i < g_text_len && (b[i] == ' ' || b[i] == '\t')) i++;
        while (i < g_text_len && b[i] >= '0' && b[i] <= '9') v = v * 10 + (uint64_t)(b[i++] - '0');
        while (i < g_text_len && b[i] != '\n') i++;
        if (u != v) keys[cnt++] = u < v ? (u << 32 | v) : (v << 32 | u);
    }
    qsort(keys, cnt, 8, cmp_u64);
    size_t w = 0;
    for (size_t k = 0; k < cnt; k++)
        if (w == 0 || keys[w - 1] != keys[k]) keys[w++] = keys[k];
    uint32_t *pu = malloc(w * 4), *pv = malloc(w * 4);
    for (size_t k = 0; k < w; k++) {
        pu[k] = (uint32_t)(keys[k] >> 32);
        pv[k] = (uint32_t)(keys[k] & 0xffffffffu);
    }
    free(keys);
    uint64_t *off;
    uint32_t *tgt;
    size_t tl;
    radix_build(n, pu, pv, w, T, &off, &tgt, &tl);
    double dt = now_s() - t0;
    free(off);
    free(tgt);
    free(pu);
    free(pv);
    return dt;
}

/* ---------- relabel + orient stages -------------------------------------- */
static const uint64_t *o_off;
static const uint32_t *o_tgt;
static uint32_t *o_deg;
static uint64_t *o_ooff;
static uint32_t *o_otgt;
static void deg_phase(int p, size_t lo, size_t hi) {
    (void)p;
    for (size_t v = lo; v < hi; v++) o_deg[v] = (uint32_t)(o_off[v + 1] - o_off[v]);
}
static int precedes(uint32_t du, uint32_t u, uint32_t dv, uint32_t v) {
    return du < dv || (du == dv && u < v);
}
static void ocount_phase(int p, size_t lo, size_t hi) {
    (void)p;
    for (size_t v = lo; v < hi; v++) {
        uint64_t c = 0;
        for (size_t i = o_off[v]; i < o_off[v + 1]; i++)
            if (precedes(o_deg[v], (uint32_t)v, o_deg[o_tgt[i]], o_tgt[i])) c++;
        o_ooff[v + 1] = c;
    }
}
static void ofill_phase(int p, size_t lo, size_t hi) {
    (void)p;
    for (size_t v = lo; v < hi; v++) {
        size_t w = o_ooff[v];
        for (size_t i = o_off[v]; i < o_off[v + 1]; i++)
            if (precedes(o_deg[v], (uint32_t)v, o_deg[o_tgt[i]], o_tgt[i])) o_otgt[w++] = o_tgt[i];
    }
}
static size_t orient_hubs;
static int cmp_cand(const void *a, const void *b) {
    uint32_t x = *(const uint32_t *)a, y = *(const uint32_t *)b;
    uint64_t lx = o_ooff[x + 1] - o_ooff[x], ly = o_ooff[y + 1] - o_ooff[y];
    if (lx != ly) return lx > ly ? -1 : 1;
    return x < y ? -1 : 1;
}
static double orient_stage(uint32_t n, const uint64_t *off, const uint32_t *tgt, int T) {
    double t0 = now_s();
    o_off = off;
    o_tgt = tgt;
    o_deg = malloc((size_t)n * 4);
    par_for(T, n, deg_phase);
    o_ooff = calloc(n + 1, 8);
    par_for(T, n, ocount_phase);
    for (uint32_t v = 0; v < n; v++) o_ooff[v + 1] += o_ooff[v];
    o_otgt = malloc(o_ooff[n] * 4);
    par_for(T, n, ofill_phase);
    /* hub bitmap packing: rows with d^ >= 32, heaviest first, 4*m-byte
     * span budget (the auto rule of adj/hub.rs). */
    uint64_t budget = 4 * o_ooff[n];
    uint32_t *cand = malloc((size_t)n * 4);
    size_t nc = 0;
    for (uint32_t v = 0; v < n; v++)
        if (o_ooff[v + 1] - o_ooff[v] >= 32) cand[nc++] = v;
    /* sort candidates heaviest-first, ties by id (the auto rule) */
    qsort(cand, nc, 4, cmp_cand);
    uint64_t spent = 0;
    orient_hubs = 0;
    for (size_t k = 0; k < nc; k++) {
        uint32_t v = cand[k];
        size_t s = o_ooff[v], e = o_ooff[v + 1];
        uint64_t w0 = o_otgt[s] / 64, w1 = o_otgt[e - 1] / 64;
        uint64_t bytes = 8 * (w1 - w0 + 1);
        if (spent + bytes > budget) continue;
        spent += bytes;
        uint64_t *words = calloc(w1 - w0 + 1, 8);
        for (size_t i = s; i < e; i++) words[o_otgt[i] / 64 - w0] |= 1ull << (o_otgt[i] % 64);
        free(words);
        orient_hubs++;
    }
    free(cand);
    double dt = now_s() - t0;
    free(o_deg);
    free(o_ooff);
    free(o_otgt);
    return dt;
}

static double relabel_stage(uint32_t n, const uint64_t *off, const uint32_t *tgt, int T,
                            uint64_t **roff, uint32_t **rtgt, size_t *rtl) {
    double t0 = now_s();
    /* counting-sort permutation by (degree, id) */
    uint32_t dmax = 0;
    for (uint32_t v = 0; v < n; v++) {
        uint32_t d = (uint32_t)(off[v + 1] - off[v]);
        if (d > dmax) dmax = d;
    }
    uint64_t *start = calloc((size_t)dmax + 2, 8);
    for (uint32_t v = 0; v < n; v++) start[(off[v + 1] - off[v]) + 1]++;
    for (uint32_t d = 0; d <= dmax; d++) start[d + 1] += start[d];
    uint32_t *perm = malloc((size_t)n * 4);
    for (uint32_t v = 0; v < n; v++) perm[v] = (uint32_t)start[off[v + 1] - off[v]]++;
    free(start);
    /* map edges (u < v half) and rebuild through the radix path */
    size_t m = off[n] / 2;
    uint32_t *mu = malloc(m * 4), *mv = malloc(m * 4);
    size_t w = 0;
    for (uint32_t u = 0; u < n; u++)
        for (size_t i = off[u]; i < off[u + 1]; i++)
            if (u < tgt[i]) {
                mu[w] = perm[u];
                mv[w] = perm[tgt[i]];
                w++;
            }
    radix_build(n, mu, mv, w, T, roff, rtgt, rtl);
    double dt = now_s() - t0;
    free(perm);
    free(mu);
    free(mv);
    return dt;
}

/* ---------- generators ---------------------------------------------------- */
static void gen_pa(uint32_t n, uint32_t d, uint32_t **eu, uint32_t **ev, size_t *m) {
    size_t half = d / 2, cap = (size_t)n * half;
    uint32_t *u = malloc(cap * 4), *v = malloc(cap * 4);
    uint32_t *ends = malloc(2 * cap * 4);
    size_t ne = 0, me = 0;
    for (uint32_t s = 1; s <= half && s < n; s++) { /* seed path */
        u[me] = s - 1;
        v[me] = s;
        ends[ne++] = s - 1;
        ends[ne++] = s;
        me++;
    }
    for (uint32_t s = (uint32_t)half + 1; s < n; s++) {
        for (size_t k = 0; k < half; k++) {
            uint32_t t;
            do {
                t = (rng_next() & 1) ? ends[rng_below((uint32_t)ne)] : rng_below(s);
            } while (t == s);
            u[me] = s;
            v[me] = t;
            ends[ne++] = s;
            ends[ne++] = t;
            me++;
        }
    }
    free(ends);
    *eu = u;
    *ev = v;
    *m = me;
}
static void gen_rmat(uint32_t scale, uint32_t ef, uint32_t **eu, uint32_t **ev, size_t *m) {
    uint32_t n = 1u << scale;
    size_t me = (size_t)n * ef / 2;
    uint32_t *u = malloc(me * 4), *v = malloc(me * 4);
    size_t w = 0;
    while (w < me) {
        uint32_t a = 0, b = 0;
        for (uint32_t bit = 0; bit < scale; bit++) {
            uint32_t r = (uint32_t)(rng_next() % 100);
            /* (a,b,c,d) = (57,19,19,5) */
            uint32_t qa = r < 57, qb = !qa && r < 76, qc = !qa && !qb && r < 95;
            a = (a << 1) | (qc || (!qa && !qb && !qc));
            b = (b << 1) | (qb || (!qa && !qb && !qc));
        }
        if (a == b) continue;
        u[w] = a;
        v[w] = b;
        w++;
    }
    *eu = u;
    *ev = v;
    *m = w;
}
static void gen_er(uint32_t n, uint32_t d, uint32_t **eu, uint32_t **ev, size_t *m) {
    size_t me = (size_t)n * d / 2;
    uint32_t *u = malloc(me * 4), *v = malloc(me * 4);
    size_t w = 0;
    while (w < me) {
        uint32_t a = rng_below(n), b = rng_below(n);
        if (a == b) continue;
        u[w] = a;
        v[w] = b;
        w++;
    }
    *eu = u;
    *ev = v;
    *m = w;
}

/* ---------- driver -------------------------------------------------------- */
static double median3(double a, double b, double c) {
    if ((a <= b && b <= c) || (c <= b && b <= a)) return b;
    if ((b <= a && a <= c) || (c <= a && a <= b)) return a;
    return c;
}

int main(void) {
    const char *names[3] = {"pa:100000:64", "rmat:16:16", "er:200000:16"};
    const int threads[4] = {1, 2, 4, 8};
    int first_row = 1;
    printf("{\n  \"columns\": [\"workload\", \"n\", \"m\", \"threads\", \"parse_s\", "
           "\"build_radix_s\", \"build_sort_s\", \"relabel_s\", \"orient_hub_s\", "
           "\"total_s\", \"speedup_vs_serial\"],\n  \"rows\": [");
    for (int wl = 0; wl < 3; wl++) {
        rng_state = 0x9E3779B97F4A7C15ull + (uint64_t)wl;
        uint32_t n = 0;
        uint32_t *eu, *ev;
        size_t m;
        if (wl == 0) {
            n = 100000;
            gen_pa(n, 64, &eu, &ev, &m);
        } else if (wl == 1) {
            n = 1u << 16;
            gen_rmat(16, 16, &eu, &ev, &m);
        } else {
            n = 200000;
            gen_er(n, 16, &eu, &ev, &m);
        }
        make_text(eu, ev, m);
        /* serial comparison-sort reference + its timing */
        double s1 = 0, s2 = 0, s3 = 0;
        uint64_t *soff = NULL;
        uint32_t *stgt = NULL;
        size_t stl = 0;
        for (int r = 0; r < 3; r++) {
            if (soff) {
                free(soff);
                free(stgt);
            }
            double t0 = now_s();
            sort_build(n, eu, ev, m, &soff, &stgt, &stl);
            double dt = now_s() - t0;
            if (r == 0) s1 = dt;
            if (r == 1) s2 = dt;
            if (r == 2) s3 = dt;
        }
        double sort_s = median3(s1, s2, s3);
        double serial_total = 0;
        for (int ti = 0; ti < 4; ti++) {
            int T = threads[ti];
            double ps[3], bs[3], rs[3], os[3];
            for (int r = 0; r < 3; r++) {
                ps[r] = parse_stage(n, m, T);
                uint64_t *off;
                uint32_t *tgt;
                size_t tl;
                double t0 = now_s();
                radix_build(n, eu, ev, m, T, &off, &tgt, &tl);
                bs[r] = now_s() - t0;
                /* verify: bit-identical to the comparison-sort build */
                if (tl != stl || memcmp(off, soff, (n + 1) * 8) ||
                    memcmp(tgt, stgt, tl * 4)) {
                    fprintf(stderr, "DIVERGENCE at %s T=%d\n", names[wl], T);
                    return 1;
                }
                uint64_t *roff;
                uint32_t *rtgt;
                size_t rtl;
                rs[r] = relabel_stage(n, off, tgt, T, &roff, &rtgt, &rtl);
                os[r] = orient_stage(n, roff, rtgt, T);
                free(off);
                free(tgt);
                free(roff);
                free(rtgt);
            }
            double p = median3(ps[0], ps[1], ps[2]), b = median3(bs[0], bs[1], bs[2]);
            double rl = median3(rs[0], rs[1], rs[2]), o = median3(os[0], os[1], os[2]);
            double tot = p + b + rl + o;
            if (T == 1) serial_total = tot;
            printf("%s\n    {\"workload\": \"%s\", \"n\": %u, \"m\": %zu, \"threads\": %d, "
                   "\"parse_s\": %.6f, \"build_radix_s\": %.6f, \"build_sort_s\": %.6f, "
                   "\"relabel_s\": %.6f, \"orient_hub_s\": %.6f, \"total_s\": %.6f, "
                   "\"speedup_vs_serial\": %.3f}",
                   first_row ? "" : ",", names[wl], n, m, T, p, b, sort_s, rl, o, tot,
                   serial_total / tot);
            first_row = 0;
            fflush(stdout);
        }
        free(soff);
        free(stgt);
        free(eu);
        free(ev);
        free(g_text);
    }
    printf("\n  ],\n  \"notes\": [");
    printf("\"determinism verified for the C mirror only: its radix CSR == its comparison-sort "
           "CSR at every thread count above (cores on this host: %ld); the Rust implementation "
           "is verified by its own property tests + the CI bench-pipeline smoke step\", ",
           sysconf(_SC_NPROCESSORS_ONLN));
    printf("\"build_sort_s = the seed's serial comparison-sort builder, the timing baseline "
           "the radix build replaces\", ");
    printf("\"harness: tools/bench_pipeline_prototype.c — a C mirror of the Rust pipeline "
           "(the PR-3 authoring container ships no Rust toolchain); regenerate natively "
           "with `cargo run --release -- bench-pipeline`, which emits this same schema\"");
    printf("]\n}\n");
    return 0;
}
