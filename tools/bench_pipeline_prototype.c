/* bench_pipeline_prototype.c — measured stand-in for `tricount bench-pipeline`.
 *
 * The authoring container ships no Rust toolchain, so the committed
 * BENCH_pipeline.json is produced by this C mirror of the exact
 * algorithms in rust/src/graph/builder.rs (O(m) two-pass counting/radix CSR
 * build with disjoint per-(thread,bucket) scatter regions vs. the seed's
 * comparison-sort build), rust/src/graph/io.rs (chunk-parallel byte parse
 * split at newline boundaries + the zero-parse `.tcg` binary loader with
 * its FNV-1a integrity footer), rust/src/graph/relabel.rs (counting-sort
 * permutation), rust/src/graph/ordering.rs (parallel orientation + hub
 * bitmap packing) and rust/src/intersect.rs (the SWAR u64-blocked
 * intersection tier, measured against the scalar merge as a note).
 * Thread requests are clamped to the host's cores, mirroring
 * par::clamp_to_host — an oversubscribed request must cost what the
 * clamped one does, not regress.
 * Regenerate natively with:  cargo run --release -- bench-pipeline
 * (CI runs a small-preset smoke of the native path on every push.)
 *
 * Build/run:  gcc -O2 -pthread -o /tmp/bpp tools/bench_pipeline_prototype.c
 *             /tmp/bpp > BENCH_pipeline.json
 *
 * The prototype verifies, like the native subcommand, that the radix build
 * at every thread count is byte-identical to the comparison-sort build,
 * that the chunk-parallel parse is byte-identical to the serial parse, and
 * that the `.tcg` reload is byte-identical to the CSR written — and exits
 * nonzero on any divergence.
 */
#include <pthread.h>
#include <stdint.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>
#include <time.h>
#include <unistd.h>

static double now_s(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return (double)ts.tv_sec + 1e-9 * (double)ts.tv_nsec;
}

/* ---------- tiny parallel-for (same near-equal chunking as par::ranges) -- */
typedef void (*part_fn)(int part, size_t lo, size_t hi);
typedef struct {
    part_fn fn;
    int part;
    size_t lo, hi;
} job_t;
static void *job_main(void *p) {
    job_t *j = (job_t *)p;
    j->fn(j->part, j->lo, j->hi);
    return NULL;
}
static void par_for(int t, size_t len, part_fn fn) {
    if (t <= 1) {
        fn(0, 0, len);
        return;
    }
    pthread_t th[64];
    job_t jobs[64];
    size_t base = len / (size_t)t, rem = len % (size_t)t, at = 0;
    for (int i = 0; i < t; i++) {
        size_t sz = base + ((size_t)i < rem ? 1 : 0);
        jobs[i] = (job_t){fn, i, at, at + sz};
        at += sz;
        pthread_create(&th[i], NULL, job_main, &jobs[i]);
    }
    for (int i = 0; i < t; i++) pthread_join(th[i], NULL);
}

/* ---------- rng ---------------------------------------------------------- */
static uint64_t rng_state;
static uint64_t rng_next(void) {
    uint64_t x = rng_state;
    x ^= x << 13;
    x ^= x >> 7;
    x ^= x << 17;
    return rng_state = x;
}
static uint32_t rng_below(uint32_t n) { return (uint32_t)(rng_next() % n); }

/* ---------- shared build state ------------------------------------------ */
static uint32_t g_n;
static size_t g_m; /* input edge count */
static uint32_t *g_eu, *g_ev;
static int g_T;
static uint32_t **g_hist; /* [T][n] */
static uint64_t *g_off;   /* n+1 */
static uint64_t *g_cur;   /* T*n */
static uint32_t *g_bydst; /* 2m' */
static uint32_t *g_rows;  /* 2m' */
static uint64_t *g_uniq;  /* n+1 */
static uint32_t *g_tgt;   /* final targets */
static size_t g_tgt_len;

static void norm_phase(int p, size_t lo, size_t hi) {
    uint32_t *h = g_hist[p];
    for (size_t i = lo; i < hi; i++) {
        uint32_t u = g_eu[i], v = g_ev[i];
        if (u > v) {
            g_eu[i] = v;
            g_ev[i] = u;
            u = g_eu[i];
            v = g_ev[i];
        }
        h[u]++;
        h[v]++;
    }
}
static void pass1_phase(int p, size_t lo, size_t hi) {
    uint64_t *cur = g_cur + (size_t)p * g_n;
    for (size_t i = lo; i < hi; i++) {
        uint32_t u = g_eu[i], v = g_ev[i];
        g_bydst[cur[v]++] = u;
        g_bydst[cur[u]++] = v;
    }
}
static void pass2a_phase(int p, size_t lo, size_t hi) {
    uint32_t *h = g_hist[p];
    memset(h, 0, (size_t)g_n * 4);
    for (size_t i = g_off[lo]; i < g_off[hi]; i++) h[g_bydst[i]]++;
}
static void pass2b_phase(int p, size_t lo, size_t hi) {
    uint64_t *cur = g_cur + (size_t)p * g_n;
    for (size_t v = lo; v < hi; v++)
        for (size_t i = g_off[v]; i < g_off[v + 1]; i++)
            g_rows[cur[g_bydst[i]]++] = (uint32_t)v;
}
static void dedup_phase(int p, size_t lo, size_t hi) {
    (void)p;
    for (size_t v = lo; v < hi; v++) {
        size_t s = g_off[v], e = g_off[v + 1], w = s;
        for (size_t i = s; i < e; i++) {
            uint32_t x = g_rows[i];
            if (w == s || g_rows[w - 1] != x) g_rows[w++] = x;
        }
        g_uniq[v + 1] = w - s;
    }
}
static void compact_phase(int p, size_t lo, size_t hi) {
    (void)p;
    for (size_t v = lo; v < hi; v++) {
        size_t cnt = g_uniq[v + 1] - g_uniq[v];
        memcpy(g_tgt + g_uniq[v], g_rows + g_off[v], cnt * 4);
    }
}

/* Radix build (mirror of from_edge_list_threads). Caller frees off/tgt. */
static void radix_build(uint32_t n, uint32_t *eu, uint32_t *ev, size_t m, int T,
                        uint64_t **off_out, uint32_t **tgt_out, size_t *tl_out) {
    g_n = n;
    g_m = m;
    g_eu = eu;
    g_ev = ev;
    g_T = T;
    g_hist = malloc((size_t)T * sizeof(uint32_t *));
    for (int i = 0; i < T; i++) g_hist[i] = calloc(n, 4);
    par_for(T, m, norm_phase);
    g_off = calloc(n + 1, 8);
    for (uint32_t v = 0; v < n; v++) {
        uint64_t s = 0;
        for (int i = 0; i < T; i++) s += g_hist[i][v];
        g_off[v + 1] = g_off[v] + s;
    }
    g_cur = malloc((size_t)T * n * 8);
    for (uint32_t v = 0; v < n; v++) {
        uint64_t at = g_off[v];
        for (int i = 0; i < T; i++) {
            g_cur[(size_t)i * n + v] = at;
            at += g_hist[i][v];
        }
    }
    size_t arcs = g_off[n];
    g_bydst = malloc(arcs * 4);
    par_for(T, m, pass1_phase);
    par_for(T, n, pass2a_phase);
    for (uint32_t v = 0; v < n; v++) {
        uint64_t at = g_off[v];
        for (int i = 0; i < T; i++) {
            g_cur[(size_t)i * n + v] = at;
            at += g_hist[i][v];
        }
    }
    g_rows = malloc(arcs * 4);
    par_for(T, n, pass2b_phase);
    free(g_bydst);
    g_uniq = calloc(n + 1, 8);
    par_for(T, n, dedup_phase);
    for (uint32_t v = 0; v < n; v++) g_uniq[v + 1] += g_uniq[v];
    g_tgt_len = g_uniq[n];
    g_tgt = malloc(g_tgt_len * 4);
    par_for(T, n, compact_phase);
    free(g_rows);
    free(g_cur);
    for (int i = 0; i < T; i++) free(g_hist[i]);
    free(g_hist);
    free(g_off);
    *off_out = g_uniq;
    *tgt_out = g_tgt;
    *tl_out = g_tgt_len;
}

/* Comparison-sort build (mirror of from_edge_list_sort_baseline). */
static int cmp_u64(const void *a, const void *b) {
    uint64_t x = *(const uint64_t *)a, y = *(const uint64_t *)b;
    return x < y ? -1 : x > y ? 1 : 0;
}
static int cmp_u32(const void *a, const void *b) {
    uint32_t x = *(const uint32_t *)a, y = *(const uint32_t *)b;
    return x < y ? -1 : x > y ? 1 : 0;
}
static void sort_build(uint32_t n, const uint32_t *eu, const uint32_t *ev, size_t m,
                       uint64_t **off_out, uint32_t **tgt_out, size_t *tl_out) {
    uint64_t *keys = malloc(m * 8);
    for (size_t i = 0; i < m; i++) {
        uint32_t u = eu[i], v = ev[i];
        if (u > v) {
            uint32_t t = u;
            u = v;
            v = t;
        }
        keys[i] = ((uint64_t)u << 32) | v;
    }
    qsort(keys, m, 8, cmp_u64);
    size_t w = 0;
    for (size_t i = 0; i < m; i++)
        if (w == 0 || keys[w - 1] != keys[i]) keys[w++] = keys[i];
    uint64_t *off = calloc(n + 1, 8);
    for (size_t i = 0; i < w; i++) {
        off[(keys[i] >> 32) + 1]++;
        off[(keys[i] & 0xffffffffu) + 1]++;
    }
    for (uint32_t v = 0; v < n; v++) off[v + 1] += off[v];
    uint64_t *cur = malloc((n + 1) * 8);
    memcpy(cur, off, (n + 1) * 8);
    size_t tl = off[n];
    uint32_t *tgt = malloc(tl * 4);
    for (size_t i = 0; i < w; i++) {
        uint32_t u = (uint32_t)(keys[i] >> 32), v = (uint32_t)(keys[i] & 0xffffffffu);
        tgt[cur[u]++] = v;
        tgt[cur[v]++] = u;
    }
    for (uint32_t v = 0; v < n; v++)
        qsort(tgt + off[v], off[v + 1] - off[v], 4, cmp_u32);
    free(cur);
    free(keys);
    *off_out = off;
    *tgt_out = tgt;
    *tl_out = tl;
}

/* ---------- parse stage (mirror of io.rs chunk-parallel byte scanner) ---- */
static char *g_text;
static size_t g_text_len;
static void make_text(const uint32_t *eu, const uint32_t *ev, size_t m) {
    g_text = malloc(m * 16 + 64);
    size_t at = (size_t)sprintf(g_text, "# bench prototype m=%zu\n", m);
    for (size_t i = 0; i < m; i++)
        at += (size_t)sprintf(g_text + at, "%u %u\n", eu[i], ev[i]);
    g_text_len = at;
}

/* Scan bytes [i, end) -> normalized (min,max) packed keys. Chunk bounds
 * are always cut right after a newline, so no line straddles a chunk. */
static size_t scan_range(size_t i, size_t end, uint64_t *keys) {
    const char *b = g_text;
    size_t cnt = 0;
    while (i < end) {
        while (i < end && (b[i] == ' ' || b[i] == '\t' || b[i] == '\r')) i++;
        if (i >= end) break;
        if (b[i] == '\n') {
            i++;
            continue;
        }
        if (b[i] == '#' || b[i] == '%') {
            while (i < end && b[i] != '\n') i++;
            continue;
        }
        uint64_t u = 0, v = 0;
        while (i < end && b[i] >= '0' && b[i] <= '9') u = u * 10 + (uint64_t)(b[i++] - '0');
        while (i < end && (b[i] == ' ' || b[i] == '\t')) i++;
        while (i < end && b[i] >= '0' && b[i] <= '9') v = v * 10 + (uint64_t)(b[i++] - '0');
        while (i < end && b[i] != '\n') i++;
        if (u != v) keys[cnt++] = u < v ? (u << 32 | v) : (v << 32 | u);
    }
    return cnt;
}

#define MIN_PARSE_BYTES_PER_CHUNK 4096
static size_t pp_bounds[65];
static uint64_t *pp_keys[64];
static size_t pp_cnt[64];
static void pchunk_phase(int p, size_t lo, size_t hi) {
    (void)lo;
    (void)hi;
    pp_cnt[p] = scan_range(pp_bounds[p], pp_bounds[p + 1], pp_keys[p]);
}

/* Full text-ingestion pipeline (mirror of io.rs parse_edge_list_bytes):
 * newline-aligned chunk split -> per-chunk scan into private buffers ->
 * deterministic stitch -> global sort+dedup -> radix CSR build at T. */
static void parse_text(uint32_t n, int T, uint64_t **off_out, uint32_t **tgt_out,
                       size_t *tl_out) {
    size_t by_floor = g_text_len / MIN_PARSE_BYTES_PER_CHUNK;
    int chunks = T;
    if (by_floor < (size_t)chunks) chunks = by_floor ? (int)by_floor : 1;
    pp_bounds[0] = 0;
    pp_bounds[chunks] = g_text_len;
    for (int c = 1; c < chunks; c++) {
        size_t p = g_text_len * (size_t)c / (size_t)chunks;
        while (p < g_text_len && g_text[p - 1] != '\n') p++;
        pp_bounds[c] = p;
    }
    for (int c = 0; c < chunks; c++)
        pp_keys[c] = malloc(((pp_bounds[c + 1] - pp_bounds[c]) / 4 + 2) * 8);
    par_for(chunks, (size_t)chunks, pchunk_phase);
    size_t cnt = 0;
    for (int c = 0; c < chunks; c++) cnt += pp_cnt[c];
    uint64_t *keys = malloc((cnt + 1) * 8);
    size_t at = 0;
    for (int c = 0; c < chunks; c++) {
        memcpy(keys + at, pp_keys[c], pp_cnt[c] * 8);
        at += pp_cnt[c];
        free(pp_keys[c]);
    }
    qsort(keys, cnt, 8, cmp_u64);
    size_t w = 0;
    for (size_t k = 0; k < cnt; k++)
        if (w == 0 || keys[w - 1] != keys[k]) keys[w++] = keys[k];
    uint32_t *pu = malloc(w * 4), *pv = malloc(w * 4);
    for (size_t k = 0; k < w; k++) {
        pu[k] = (uint32_t)(keys[k] >> 32);
        pv[k] = (uint32_t)(keys[k] & 0xffffffffu);
    }
    free(keys);
    radix_build(n, pu, pv, w, T, off_out, tgt_out, tl_out);
    free(pu);
    free(pv);
}

/* ---------- .tcg binary format (mirror of io.rs write_tcg/read_tcg) ------ */
#define FNV_OFFSET 0xcbf29ce484222325ull
#define FNV_PRIME 0x100000001b3ull
static uint64_t fnv1a(const unsigned char *p, size_t len) {
    uint64_t h = FNV_OFFSET;
    for (size_t i = 0; i < len; i++) h = (h ^ p[i]) * FNV_PRIME;
    return h;
}
/* Layout (little-endian, same as io.rs): "TCGRAPH1" | version u32 = 1 |
 * flags u32 = 0 | n u64 | len(targets) u64 | offsets (n+1)*u64 |
 * targets len*u32 | FNV-1a u64 footer over all preceding bytes. */
static void tcg_write(const char *path, uint32_t n, const uint64_t *off,
                      const uint32_t *tgt, size_t tl) {
    size_t body = 32 + ((size_t)n + 1) * 8 + tl * 4;
    unsigned char *buf = malloc(body + 8);
    memcpy(buf, "TCGRAPH1", 8);
    uint32_t ver = 1, flags = 0;
    memcpy(buf + 8, &ver, 4);
    memcpy(buf + 12, &flags, 4);
    uint64_t n64 = n, tl64 = tl;
    memcpy(buf + 16, &n64, 8);
    memcpy(buf + 24, &tl64, 8);
    memcpy(buf + 32, off, ((size_t)n + 1) * 8);
    memcpy(buf + 32 + ((size_t)n + 1) * 8, tgt, tl * 4);
    uint64_t h = fnv1a(buf, body);
    memcpy(buf + body, &h, 8);
    FILE *f = fopen(path, "wb");
    if (!f || fwrite(buf, 1, body + 8, f) != body + 8) {
        fprintf(stderr, "tcg_write %s failed\n", path);
        exit(1);
    }
    fclose(f);
    free(buf);
}
/* Returns 1 on success (magic/version/size/footer all validated, arrays
 * bulk-copied out — the whole zero-parse load path that read_tcg times). */
static int tcg_load(const char *path, uint64_t **off_out, uint32_t **tgt_out,
                    size_t *tl_out) {
    FILE *f = fopen(path, "rb");
    if (!f) return 0;
    fseek(f, 0, SEEK_END);
    long sz = ftell(f);
    fseek(f, 0, SEEK_SET);
    if (sz < 40) {
        fclose(f);
        return 0;
    }
    unsigned char *buf = malloc((size_t)sz);
    if (fread(buf, 1, (size_t)sz, f) != (size_t)sz) {
        fclose(f);
        free(buf);
        return 0;
    }
    fclose(f);
    uint32_t ver;
    memcpy(&ver, buf + 8, 4);
    uint64_t n64, tl64;
    memcpy(&n64, buf + 16, 8);
    memcpy(&tl64, buf + 24, 8);
    size_t body = 32 + ((size_t)n64 + 1) * 8 + (size_t)tl64 * 4;
    if (memcmp(buf, "TCGRAPH1", 8) || ver != 1 || (size_t)sz != body + 8) {
        free(buf);
        return 0;
    }
    uint64_t footer;
    memcpy(&footer, buf + body, 8);
    if (fnv1a(buf, body) != footer) {
        free(buf);
        return 0;
    }
    uint64_t *off = malloc(((size_t)n64 + 1) * 8);
    memcpy(off, buf + 32, ((size_t)n64 + 1) * 8);
    uint32_t *tgt = malloc((size_t)tl64 * 4);
    memcpy(tgt, buf + 32 + ((size_t)n64 + 1) * 8, (size_t)tl64 * 4);
    free(buf);
    *off_out = off;
    *tgt_out = tgt;
    *tl_out = (size_t)tl64;
    return 1;
}

/* ---------- SWAR blocked intersection (mirror of count_simd_blocked) ----- */
static uint64_t isect_merge(const uint32_t *a, size_t la, const uint32_t *b, size_t lb) {
    size_t i = 0, j = 0;
    uint64_t c = 0;
    while (i < la && j < lb) {
        uint32_t x = a[i], y = b[j];
        c += x == y;
        i += x <= y;
        j += y <= x;
    }
    return c;
}
static uint64_t isect_blocked(const uint32_t *a, size_t la, const uint32_t *b, size_t lb) {
    if (la > lb) {
        const uint32_t *tp = a;
        a = b;
        b = tp;
        size_t tl = la;
        la = lb;
        lb = tl;
    }
    size_t i = 0, j = 0;
    uint64_t c = 0;
    while (i + 2 <= la && j + 4 <= lb) {
        uint32_t a0 = a[i], a1 = a[i + 1];
        uint32_t b0 = b[j], b1 = b[j + 1], b2 = b[j + 2], b3 = b[j + 3];
        uint64_t wa = (uint64_t)a0 | ((uint64_t)a1 << 32);
        uint64_t wr = (uint64_t)a1 | ((uint64_t)a0 << 32);
        uint64_t wb0 = (uint64_t)b0 | ((uint64_t)b1 << 32);
        uint64_t wb1 = (uint64_t)b2 | ((uint64_t)b3 << 32);
        uint64_t z0 = wa ^ wb0, z1 = wr ^ wb0, z2 = wa ^ wb1, z3 = wr ^ wb1;
        c += (uint64_t)((z0 & 0xffffffffull) == 0) + (uint64_t)((z0 >> 32) == 0) +
             (uint64_t)((z1 & 0xffffffffull) == 0) + (uint64_t)((z1 >> 32) == 0) +
             (uint64_t)((z2 & 0xffffffffull) == 0) + (uint64_t)((z2 >> 32) == 0) +
             (uint64_t)((z3 & 0xffffffffull) == 0) + (uint64_t)((z3 >> 32) == 0);
        i += 2 * (size_t)(a1 <= b3);
        j += 4 * (size_t)(b3 <= a1);
    }
    return c + isect_merge(a + i, la - i, b + j, lb - j);
}
static size_t make_sorted_list(uint32_t len, uint32_t universe, uint32_t *out) {
    for (uint32_t i = 0; i < len; i++) out[i] = rng_below(universe);
    qsort(out, len, 4, cmp_u32);
    size_t w = 0;
    for (uint32_t i = 0; i < len; i++)
        if (w == 0 || out[w - 1] != out[i]) out[w++] = out[i];
    return w;
}

/* ---------- relabel + orient stages -------------------------------------- */
static const uint64_t *o_off;
static const uint32_t *o_tgt;
static uint32_t *o_deg;
static uint64_t *o_ooff;
static uint32_t *o_otgt;
static void deg_phase(int p, size_t lo, size_t hi) {
    (void)p;
    for (size_t v = lo; v < hi; v++) o_deg[v] = (uint32_t)(o_off[v + 1] - o_off[v]);
}
static int precedes(uint32_t du, uint32_t u, uint32_t dv, uint32_t v) {
    return du < dv || (du == dv && u < v);
}
static void ocount_phase(int p, size_t lo, size_t hi) {
    (void)p;
    for (size_t v = lo; v < hi; v++) {
        uint64_t c = 0;
        for (size_t i = o_off[v]; i < o_off[v + 1]; i++)
            if (precedes(o_deg[v], (uint32_t)v, o_deg[o_tgt[i]], o_tgt[i])) c++;
        o_ooff[v + 1] = c;
    }
}
static void ofill_phase(int p, size_t lo, size_t hi) {
    (void)p;
    for (size_t v = lo; v < hi; v++) {
        size_t w = o_ooff[v];
        for (size_t i = o_off[v]; i < o_off[v + 1]; i++)
            if (precedes(o_deg[v], (uint32_t)v, o_deg[o_tgt[i]], o_tgt[i])) o_otgt[w++] = o_tgt[i];
    }
}
static size_t orient_hubs;
static int cmp_cand(const void *a, const void *b) {
    uint32_t x = *(const uint32_t *)a, y = *(const uint32_t *)b;
    uint64_t lx = o_ooff[x + 1] - o_ooff[x], ly = o_ooff[y + 1] - o_ooff[y];
    if (lx != ly) return lx > ly ? -1 : 1;
    return x < y ? -1 : 1;
}
static double orient_stage(uint32_t n, const uint64_t *off, const uint32_t *tgt, int T) {
    double t0 = now_s();
    o_off = off;
    o_tgt = tgt;
    o_deg = malloc((size_t)n * 4);
    par_for(T, n, deg_phase);
    o_ooff = calloc(n + 1, 8);
    par_for(T, n, ocount_phase);
    for (uint32_t v = 0; v < n; v++) o_ooff[v + 1] += o_ooff[v];
    o_otgt = malloc(o_ooff[n] * 4);
    par_for(T, n, ofill_phase);
    /* hub bitmap packing: rows with d^ >= 32, heaviest first, 4*m-byte
     * span budget (the auto rule of adj/hub.rs). */
    uint64_t budget = 4 * o_ooff[n];
    uint32_t *cand = malloc((size_t)n * 4);
    size_t nc = 0;
    for (uint32_t v = 0; v < n; v++)
        if (o_ooff[v + 1] - o_ooff[v] >= 32) cand[nc++] = v;
    /* sort candidates heaviest-first, ties by id (the auto rule) */
    qsort(cand, nc, 4, cmp_cand);
    uint64_t spent = 0;
    orient_hubs = 0;
    for (size_t k = 0; k < nc; k++) {
        uint32_t v = cand[k];
        size_t s = o_ooff[v], e = o_ooff[v + 1];
        uint64_t w0 = o_otgt[s] / 64, w1 = o_otgt[e - 1] / 64;
        uint64_t bytes = 8 * (w1 - w0 + 1);
        if (spent + bytes > budget) continue;
        spent += bytes;
        uint64_t *words = calloc(w1 - w0 + 1, 8);
        for (size_t i = s; i < e; i++) words[o_otgt[i] / 64 - w0] |= 1ull << (o_otgt[i] % 64);
        free(words);
        orient_hubs++;
    }
    free(cand);
    double dt = now_s() - t0;
    free(o_deg);
    free(o_ooff);
    free(o_otgt);
    return dt;
}

static double relabel_stage(uint32_t n, const uint64_t *off, const uint32_t *tgt, int T,
                            uint64_t **roff, uint32_t **rtgt, size_t *rtl) {
    double t0 = now_s();
    /* counting-sort permutation by (degree, id) */
    uint32_t dmax = 0;
    for (uint32_t v = 0; v < n; v++) {
        uint32_t d = (uint32_t)(off[v + 1] - off[v]);
        if (d > dmax) dmax = d;
    }
    uint64_t *start = calloc((size_t)dmax + 2, 8);
    for (uint32_t v = 0; v < n; v++) start[(off[v + 1] - off[v]) + 1]++;
    for (uint32_t d = 0; d <= dmax; d++) start[d + 1] += start[d];
    uint32_t *perm = malloc((size_t)n * 4);
    for (uint32_t v = 0; v < n; v++) perm[v] = (uint32_t)start[off[v + 1] - off[v]]++;
    free(start);
    /* map edges (u < v half) and rebuild through the radix path */
    size_t m = off[n] / 2;
    uint32_t *mu = malloc(m * 4), *mv = malloc(m * 4);
    size_t w = 0;
    for (uint32_t u = 0; u < n; u++)
        for (size_t i = off[u]; i < off[u + 1]; i++)
            if (u < tgt[i]) {
                mu[w] = perm[u];
                mv[w] = perm[tgt[i]];
                w++;
            }
    radix_build(n, mu, mv, w, T, roff, rtgt, rtl);
    double dt = now_s() - t0;
    free(perm);
    free(mu);
    free(mv);
    return dt;
}

/* ---------- generators ---------------------------------------------------- */
static void gen_pa(uint32_t n, uint32_t d, uint32_t **eu, uint32_t **ev, size_t *m) {
    size_t half = d / 2, cap = (size_t)n * half;
    uint32_t *u = malloc(cap * 4), *v = malloc(cap * 4);
    uint32_t *ends = malloc(2 * cap * 4);
    size_t ne = 0, me = 0;
    for (uint32_t s = 1; s <= half && s < n; s++) { /* seed path */
        u[me] = s - 1;
        v[me] = s;
        ends[ne++] = s - 1;
        ends[ne++] = s;
        me++;
    }
    for (uint32_t s = (uint32_t)half + 1; s < n; s++) {
        for (size_t k = 0; k < half; k++) {
            uint32_t t;
            do {
                t = (rng_next() & 1) ? ends[rng_below((uint32_t)ne)] : rng_below(s);
            } while (t == s);
            u[me] = s;
            v[me] = t;
            ends[ne++] = s;
            ends[ne++] = t;
            me++;
        }
    }
    free(ends);
    *eu = u;
    *ev = v;
    *m = me;
}
static void gen_rmat(uint32_t scale, uint32_t ef, uint32_t **eu, uint32_t **ev, size_t *m) {
    uint32_t n = 1u << scale;
    size_t me = (size_t)n * ef / 2;
    uint32_t *u = malloc(me * 4), *v = malloc(me * 4);
    size_t w = 0;
    while (w < me) {
        uint32_t a = 0, b = 0;
        for (uint32_t bit = 0; bit < scale; bit++) {
            uint32_t r = (uint32_t)(rng_next() % 100);
            /* (a,b,c,d) = (57,19,19,5) */
            uint32_t qa = r < 57, qb = !qa && r < 76, qc = !qa && !qb && r < 95;
            a = (a << 1) | (qc || (!qa && !qb && !qc));
            b = (b << 1) | (qb || (!qa && !qb && !qc));
        }
        if (a == b) continue;
        u[w] = a;
        v[w] = b;
        w++;
    }
    *eu = u;
    *ev = v;
    *m = w;
}
static void gen_er(uint32_t n, uint32_t d, uint32_t **eu, uint32_t **ev, size_t *m) {
    size_t me = (size_t)n * d / 2;
    uint32_t *u = malloc(me * 4), *v = malloc(me * 4);
    size_t w = 0;
    while (w < me) {
        uint32_t a = rng_below(n), b = rng_below(n);
        if (a == b) continue;
        u[w] = a;
        v[w] = b;
        w++;
    }
    *eu = u;
    *ev = v;
    *m = w;
}

/* ---------- driver -------------------------------------------------------- */
#define REPS 5
static int cmp_dbl(const void *a, const void *b) {
    double x = *(const double *)a, y = *(const double *)b;
    return x < y ? -1 : x > y ? 1 : 0;
}
/* Per-stage aggregate: median-of-REPS (same estimator as the native
 * subcommand). Reps are INTERLEAVED across thread counts — rep r times
 * the serial references and every T row back-to-back — so slow drift on
 * a busy shared host hits all rows equally instead of penalizing
 * whichever row happens to be measured last. */
static double med(double *xs, int k) {
    qsort(xs, (size_t)k, sizeof(double), cmp_dbl);
    return xs[k / 2];
}

static int same_csr(uint32_t n, const uint64_t *ao, const uint32_t *at, size_t al,
                    const uint64_t *bo, const uint32_t *bt, size_t bl) {
    return al == bl && !memcmp(ao, bo, ((size_t)n + 1) * 8) && !memcmp(at, bt, al * 4);
}

int main(void) {
    const char *names[3] = {"pa:100000:64", "rmat:16:16", "er:200000:16"};
    const int threads[4] = {1, 2, 4, 8};
    long cores = sysconf(_SC_NPROCESSORS_ONLN);
    if (cores < 1) cores = 1;
    int first_row = 1;
    printf("{\n  \"columns\": [\"workload\", \"n\", \"m\", \"threads\", \"parse_s\", "
           "\"parse_text_par_s\", \"load_tcg_s\", \"build_radix_s\", \"build_sort_s\", "
           "\"relabel_s\", \"orient_hub_s\", \"total_s\", \"speedup_vs_serial\"],\n  \"rows\": [");
    for (int wl = 0; wl < 3; wl++) {
        rng_state = 0x9E3779B97F4A7C15ull + (uint64_t)wl;
        uint32_t n = 0;
        uint32_t *eu, *ev;
        size_t m;
        if (wl == 0) {
            n = 100000;
            gen_pa(n, 64, &eu, &ev, &m);
        } else if (wl == 1) {
            n = 1u << 16;
            gen_rmat(16, 16, &eu, &ev, &m);
        } else {
            n = 200000;
            gen_er(n, 16, &eu, &ev, &m);
        }
        make_text(eu, ev, m);
        /* Untimed reference pass: the comparison-sort CSR and the serial
         * parse CSR every timed run below is checked against. */
        uint64_t *soff, *poff;
        uint32_t *stgt, *ptgt;
        size_t stl, ptl;
        sort_build(n, eu, ev, m, &soff, &stgt, &stl);
        parse_text(n, 1, &poff, &ptgt, &ptl);
        if (!same_csr(n, poff, ptgt, ptl, soff, stgt, stl)) {
            fprintf(stderr, "PARSE/SORT DIVERGENCE at %s\n", names[wl]);
            return 1;
        }
        /* zero-parse .tcg reload of the same CSR (per-workload constant),
         * equality-gated against the CSR written. */
        char tcg_path[64];
        snprintf(tcg_path, sizeof tcg_path, "/tmp/bpp_%d.tcg", wl);
        tcg_write(tcg_path, n, soff, stgt, stl);
        double l1[REPS];
        for (int r = 0; r < REPS; r++) {
            uint64_t *loff;
            uint32_t *ltgt;
            size_t ltl;
            double t0 = now_s();
            if (!tcg_load(tcg_path, &loff, &ltgt, &ltl)) {
                fprintf(stderr, ".tcg LOAD FAILED at %s\n", names[wl]);
                return 1;
            }
            l1[r] = now_s() - t0;
            if (!same_csr(n, loff, ltgt, ltl, soff, stgt, stl)) {
                fprintf(stderr, ".tcg ROUND-TRIP DIVERGENCE at %s\n", names[wl]);
                return 1;
            }
            free(loff);
            free(ltgt);
        }
        unlink(tcg_path);
        double load_tcg_s = med(l1, REPS);
        /* par::clamp_to_host mirror: requested thread counts clamp to the
         * host's cores, so distinct requests can resolve to the SAME
         * effective count — those rows execute identical code by
         * construction and share one measurement set (re-measuring an
         * identical configuration only records scheduler noise as phantom
         * regressions). */
        int effs[4], row_eff[4], neff = 0;
        for (int ti = 0; ti < 4; ti++) {
            int eff = threads[ti] > (int)cores ? (int)cores : threads[ti];
            if (neff == 0 || effs[neff - 1] != eff) effs[neff++] = eff;
            row_eff[ti] = neff - 1;
        }
        /* Interleaved timing pass: rep r measures the serial references and
         * every distinct effective thread count back-to-back (drift
         * fairness, see med()). */
        double ss[REPS], p1[REPS];
        double ps[4][REPS], bs[4][REPS], rs[4][REPS], os[4][REPS];
        for (int r = 0; r < REPS; r++) {
            uint64_t *off;
            uint32_t *tgt;
            size_t tl;
            double t0 = now_s();
            sort_build(n, eu, ev, m, &off, &tgt, &tl);
            ss[r] = now_s() - t0;
            free(off);
            free(tgt);
            t0 = now_s();
            parse_text(n, 1, &off, &tgt, &tl);
            p1[r] = now_s() - t0;
            free(off);
            free(tgt);
            for (int e = 0; e < neff; e++) {
                int eff = effs[e];
                if (eff == 1) {
                    /* At one effective thread the chunked parser takes the
                     * single-chunk path — the serial parse just timed. */
                    ps[e][r] = p1[r];
                } else {
                    t0 = now_s();
                    parse_text(n, eff, &off, &tgt, &tl);
                    ps[e][r] = now_s() - t0;
                    if (!same_csr(n, off, tgt, tl, poff, ptgt, ptl)) {
                        fprintf(stderr, "CHUNKED-PARSE DIVERGENCE at %s T=%d\n", names[wl], eff);
                        return 1;
                    }
                    free(off);
                    free(tgt);
                }
                t0 = now_s();
                radix_build(n, eu, ev, m, eff, &off, &tgt, &tl);
                bs[e][r] = now_s() - t0;
                /* verify: bit-identical to the comparison-sort build */
                if (!same_csr(n, off, tgt, tl, soff, stgt, stl)) {
                    fprintf(stderr, "DIVERGENCE at %s T=%d\n", names[wl], eff);
                    return 1;
                }
                uint64_t *roff;
                uint32_t *rtgt;
                size_t rtl;
                rs[e][r] = relabel_stage(n, off, tgt, eff, &roff, &rtgt, &rtl);
                os[e][r] = orient_stage(n, roff, rtgt, eff);
                free(off);
                free(tgt);
                free(roff);
                free(rtgt);
            }
        }
        double sort_s = med(ss, REPS), parse_s = med(p1, REPS);
        double serial_total = 0;
        for (int ti = 0; ti < 4; ti++) {
            int T = threads[ti];
            int e = row_eff[ti];
            double pp = med(ps[e], REPS), b = med(bs[e], REPS);
            double rl = med(rs[e], REPS), o = med(os[e], REPS);
            double tot = pp + b + rl + o;
            if (T == 1) serial_total = tot;
            printf("%s\n    {\"workload\": \"%s\", \"n\": %u, \"m\": %zu, \"threads\": %d, "
                   "\"parse_s\": %.6f, \"parse_text_par_s\": %.6f, \"load_tcg_s\": %.6f, "
                   "\"build_radix_s\": %.6f, \"build_sort_s\": %.6f, \"relabel_s\": %.6f, "
                   "\"orient_hub_s\": %.6f, \"total_s\": %.6f, \"speedup_vs_serial\": %.3f}",
                   first_row ? "" : ",", names[wl], n, m, T, parse_s, pp, load_tcg_s, b,
                   sort_s, rl, o, tot, serial_total / tot);
            first_row = 0;
            fflush(stdout);
        }
        free(poff);
        free(ptgt);
        free(soff);
        free(stgt);
        free(eu);
        free(ev);
        free(g_text);
    }
    /* SWAR blocked-tier microbench: balanced 10K∩10K, scalar merge vs the
     * u64-blocked kernel, differential-checked, recorded as a note (the
     * native table is benches/hot_path.rs). */
    rng_state = 0x9E3779B97F4A7C15ull;
    uint32_t *ba = malloc(10000 * 4), *bb = malloc(10000 * 4);
    size_t la = make_sorted_list(10000, 1000000, ba);
    size_t lb = make_sorted_list(10000, 1000000, bb);
    uint64_t cm = isect_merge(ba, la, bb, lb), cb = isect_blocked(ba, la, bb, lb);
    if (cm != cb) {
        fprintf(stderr, "SWAR DIVERGENCE: merge=%llu blocked=%llu\n",
                (unsigned long long)cm, (unsigned long long)cb);
        return 1;
    }
    double tm[REPS], tb[REPS];
    volatile uint64_t sink = 0;
    for (int r = 0; r < REPS; r++) {
        double t0 = now_s();
        for (int k = 0; k < 200; k++) sink += isect_merge(ba, la, bb, lb);
        tm[r] = now_s() - t0;
        t0 = now_s();
        for (int k = 0; k < 200; k++) sink += isect_blocked(ba, la, bb, lb);
        tb[r] = now_s() - t0;
    }
    (void)sink;
    double merge_ms = med(tm, REPS) * 1e3;
    double blocked_ms = med(tb, REPS) * 1e3;
    free(ba);
    free(bb);
    printf("\n  ],\n  \"notes\": [");
    printf("\"determinism verified for the C mirror only: its radix CSR == its comparison-sort "
           "CSR, its chunk-parallel parse == its serial parse, and its .tcg reload == the CSR "
           "written, at every thread count above (cores on this host: %ld; requested thread "
           "counts are clamped to the host, mirroring par::clamp_to_host); the Rust "
           "implementation is verified by its own property tests + the CI bench-pipeline, "
           "tcg-smoke and oversubscription-gate steps\", ",
           cores);
    printf("\"build_sort_s = the seed's serial comparison-sort builder, the timing baseline "
           "the radix build replaces\", ");
    printf("\"parse_s = serial byte-scan text parse (per-workload constant); "
           "parse_text_par_s = chunk-parallel parse at this row's thread count (the stage "
           "total_s includes); load_tcg_s = zero-parse binary reload of the same graph, "
           "text-vs-binary equality gated\", ");
    printf("\"this authoring host exposes %ld core(s): the host clamp resolves every "
           "requested thread count to the same effective count, and rows sharing an "
           "effective count share one measurement set (they execute identical code by "
           "construction, so re-measuring would only record scheduler noise as phantom "
           "regressions) — hence speedup_vs_serial = 1.000 on single-core hosts; the clamp "
           "is exactly what keeps oversubscribed requests from regressing (the PR-6 "
           "baseline recorded 0.700x at T=8 without it), and multi-core parse/build wins "
           "are realized on multi-core hosts and enforced by the CI bench-pipeline smoke + "
           "oversubscription gate\", ",
           cores);
    printf("\"SWAR blocked intersection tier (mirror of intersect::count_simd_blocked), "
           "balanced 10K-by-10K x200, differential-checked against the scalar merge: "
           "merge %.3f ms vs blocked %.3f ms = %.2fx; the native table is "
           "`cargo bench hot_path`\", ",
           merge_ms, blocked_ms, merge_ms / blocked_ms);
    printf("\"harness: tools/bench_pipeline_prototype.c — a C mirror of the Rust pipeline "
           "(the authoring container ships no Rust toolchain; stage times are medians of 5 reps, "
           "interleaved across thread counts for drift fairness); regenerate natively "
           "with `cargo run --release -- bench-pipeline`, which emits this same schema\"");
    printf("]\n}\n");
    return 0;
}
